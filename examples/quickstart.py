"""Quickstart: the paper in 60 seconds.

1. Simulate distributed SGD under several consistency relaxations (exact
   semantics of the paper's Algorithms 1-6), measure the elastic-consistency
   constant B, and check it against Table 1's theory bound.
2. Take one training step through the `repro.dist` API directly — the same
   ``make_train_step`` every architecture's smoke test runs.
3. Train a small transformer end-to-end with the production elastic
   scheduler (``repro.launch.train``) and watch the on-device consistency
   gap ||x - v||^2/alpha^2 tracked next to the loss.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import compression as C, theory
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate


def main():
    # --- 1. the consistency model, measured vs theory -----------------
    prob = Quadratic(dim=32, cond=8.0, sigma=1.0, seed=0)
    x0 = np.ones(32, np.float32) * 2.0
    m2 = prob.m2_estimate(float(np.sum(
        (x0 - np.asarray(prob.x_star)) ** 2)) * 1.5)
    p, alpha, T = 8, 0.02, 500

    print(f"{'relaxation':<22} {'B_hat':>8} {'B_theory':>9} {'final loss':>11}")
    cases = [
        ("perfect sync", Relaxation("sync"), 0.0),
        ("3 crash faults", Relaxation("crash", f=3),
         theory.b_crash_m(p, 3, m2)),
        ("async (tau=2)", Relaxation("async", tau_max=2),
         theory.b_async_mp(p, 2, m2)),
        ("topk-EF (25%)", Relaxation("ef_comp",
                                     compressor=C.topk_compressor(0.25)),
         theory.b_ef_compression(C.topk_gamma(32, 8), m2)),
        ("elastic scheduler", Relaxation("elastic_variance", drop_prob=0.3),
         theory.b_elastic_scheduler_variance(prob.sigma2)),
    ]
    for name, relax, bound in cases:
        res = simulate(prob, relax, p, alpha, T, seed=3, x0=x0)
        print(f"{name:<22} {res.b_hat:>8.2f} {bound:>9.2f} "
              f"{res.losses[-1]:>11.5f}")
    print("\nEvery relaxation converges, and every measured B respects the"
          "\npaper's bound — that is Theorem 2/4 + Table 1 in action.\n")

    # --- 1b. fused step + batched multi-(p, d) sweeps ------------------
    # On the quadratic testbed the scan engine fuses the whole per-step
    # pipeline (view gradients, delivery contraction, apply) into one
    # kernel call (fused="auto" picks it at d >= 128, where the fusion
    # beats the unfused scan step); simulate_grid
    # stacks same-shape problem instances x scheduler knobs x step sizes
    # x seeds into ONE compiled program instead of a loop of runs.
    from repro.core.sim import simulate_grid
    res_fused = simulate(prob, Relaxation("crash_subst", f=3), p, alpha, T,
                         seed=3, x0=x0, fused=True)
    print(f"fused crash_subst run: B_hat={res_fused.b_hat:.2f} "
          f"(same trajectory as the unfused oracle, ~2x+ steps/s at "
          f"d >= 256)")
    # fused=True: at this demo's d=32 the "auto" policy would fall back to
    # the (faster there) unfused per-problem programs; force the fused path
    # so the stacked multi-problem batch axis is what actually runs.
    grid = simulate_grid(
        problems=[Quadratic(dim=32, cond=8.0, sigma=1.0, seed=s)
                  for s in (0, 1)],
        relaxations=[Relaxation("elastic_variance", drop_prob=q)
                     for q in (0.1, 0.3)],
        p_list=p, alphas=[0.01, alpha], T=200, seeds=(0, 1), x0=x0,
        fused=True)
    b_hats = [r.b_hat for r in grid.select(i_alpha=1)]
    print(f"grid: {len(grid)} (problem x drop_prob x alpha x seed) runs in "
          f"one program; B_hat range "
          f"[{min(b_hats):.2f}, {max(b_hats):.2f}]\n")

    # --- 2. one train step through the repro.dist API ------------------
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.dist.train import loss_fn, make_train_step
    from repro.models import transformer as TF
    from repro.models.params import init_params
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b-smoke")
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    opt = momentum(3e-3, 0.9)
    batch = synthetic_batch(cfg, 4, 32, seed=0)
    step = jax.jit(make_train_step(cfg, opt, flags))
    params2, _, metrics = step(params, opt.init(params), batch)
    print(f"one make_train_step step on {cfg.name}: "
          f"loss {float(metrics['loss']):.4f} -> "
          f"{float(loss_fn(cfg, params2, batch, flags)[0]):.4f}\n")

    # --- 3. the production scheduler at smoke scale -------------------
    print("Training a smoke-scale qwen3 with the elastic scheduler")
    print("(see examples/elastic_training.py for the full comparison):")
    import subprocess
    import sys
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen3-1.7b-smoke", "--steps", "40", "--batch", "8",
         "--seq", "32", "--sync", "elastic", "--devices", "4"],
        check=True)


if __name__ == "__main__":
    main()
