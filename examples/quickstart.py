"""Quickstart: the paper in 60 seconds.

1. Simulate distributed SGD under several consistency relaxations (exact
   semantics of the paper's Algorithms 1-6), measure the elastic-consistency
   constant B, and check it against Table 1's theory bound.
2. Train a small transformer with the production elastic scheduler and watch
   the on-device consistency gap.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import compression as C, theory
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate


def main():
    # --- 1. the consistency model, measured vs theory -----------------
    prob = Quadratic(dim=32, cond=8.0, sigma=1.0, seed=0)
    x0 = np.ones(32, np.float32) * 2.0
    m2 = prob.m2_estimate(float(np.sum(
        (x0 - np.asarray(prob.x_star)) ** 2)) * 1.5)
    p, alpha, T = 8, 0.02, 500

    print(f"{'relaxation':<22} {'B_hat':>8} {'B_theory':>9} {'final loss':>11}")
    cases = [
        ("perfect sync", Relaxation("sync"), 0.0),
        ("3 crash faults", Relaxation("crash", f=3),
         theory.b_crash_m(p, 3, m2)),
        ("async (tau=2)", Relaxation("async", tau_max=2),
         theory.b_async_mp(p, 2, m2)),
        ("topk-EF (25%)", Relaxation("ef_comp",
                                     compressor=C.topk_compressor(0.25)),
         theory.b_ef_compression(C.topk_gamma(32, 8), m2)),
        ("elastic scheduler", Relaxation("elastic_variance", drop_prob=0.3),
         theory.b_elastic_scheduler_variance(prob.sigma2)),
    ]
    for name, relax, bound in cases:
        res = simulate(prob, relax, p, alpha, T, seed=3, x0=x0)
        print(f"{name:<22} {res.b_hat:>8.2f} {bound:>9.2f} "
              f"{res.losses[-1]:>11.5f}")
    print("\nEvery relaxation converges, and every measured B respects the"
          "\npaper's bound — that is Theorem 2/4 + Table 1 in action.\n")

    # --- 2. the production scheduler at smoke scale -------------------
    import importlib.util
    if importlib.util.find_spec("repro.dist") is None:
        print("repro.dist is not available in this snapshot — skipping the "
              "smoke-scale\ntraining run (see examples/elastic_training.py "
              "for the full comparison).")
        return
    print("Training a smoke-scale qwen3 with the elastic scheduler")
    print("(see examples/elastic_training.py for the full comparison):")
    import subprocess
    import sys
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen3-1.7b-smoke", "--steps", "40", "--batch", "8",
         "--seq", "32", "--sync", "elastic", "--devices", "4"],
        check=True)


if __name__ == "__main__":
    main()
