"""The elastic-consistency *budget* as a runtime knob (Def. 1 as an API).

Sweeps the norm-bounded scheduler's beta on the simulator and shows the
paper's Figure-1-left correlation: looser consistency (smaller beta / larger
measured B) -> worse final accuracy; tighter -> exact-baseline accuracy.

Run:  PYTHONPATH=src python examples/consistency_budget.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate


def accuracy(mlp, x):
    w1, b1, w2, b2 = mlp._unflatten(jnp.asarray(x))
    pred = jnp.argmax(jnp.tanh(mlp.xs @ w1 + b1) @ w2 + b2, axis=-1)
    return float(jnp.mean((pred == mlp.ys).astype(jnp.float32)))


def main():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    print(f"{'beta':>5} {'B_hat':>8} {'final loss':>11} {'accuracy':>9}")
    for beta in (0.0, 0.2, 0.5, 0.8, 1.0):
        res = simulate(mlp, Relaxation("elastic_norm", beta=beta), 8, 0.08,
                       600, seed=4, x0=x0)
        print(f"{beta:>5.1f} {res.b_hat:>8.2f} {res.losses[-1]:>11.4f} "
              f"{accuracy(mlp, res.x_final):>9.3f}")
    print("\nTighter consistency budget (higher beta) -> lower measured B "
          "-> better accuracy,\nthe correlation in the paper's Figure 1 "
          "(left).")


if __name__ == "__main__":
    main()
