"""End-to-end driver: train the same model under all four gradient-sync
strategies on a multi-device host mesh and compare loss curves + wire bytes.

This is the production code path (shard_map over the data axis, the same
SyncConfig the 256/512-chip launchers use), at CPU scale.

Run:  PYTHONPATH=src python examples/elastic_training.py [--steps 150]
"""
import argparse
import os
import subprocess
import sys


def run_one(sync: str, steps: int, devices: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b-smoke", "--steps", str(steps),
           "--batch", "16", "--seq", "32", "--sync", sync,
           "--devices", str(devices), "--log-every", str(max(steps // 5, 1))]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    final = float(out.stdout.split("final loss")[1].split()[0])
    gaps = [float(l.split("gap2/a2")[1]) for l in out.stdout.splitlines()
            if "gap2/a2" in l]
    return final, (max(gaps) if gaps else 0.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()

    print(f"{'strategy':<12} {'final loss':>11} {'max gap^2/a^2':>14}  wire")
    for sync, wire in [("exact", "dense all-reduce"),
                       ("topk_ef", "top-k values+indices (EF)"),
                       ("onebit_ef", "1-bit bitmap + means (EF)"),
                       ("elastic", "norm-gated partial sync")]:
        final, gap = run_one(sync, args.steps, args.devices)
        print(f"{sync:<12} {final:>11.4f} {gap:>14.4g}  {wire}")
    print("\nAll strategies recover the exact baseline's loss — the paper's"
          "\nclaim, on the production shard_map path.")


if __name__ == "__main__":
    main()
