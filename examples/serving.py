"""Serving example: batched prefill + decode on a smoke-scale architecture,
including a hybrid (zamba2: mamba2 + shared attention) and an attention-free
(rwkv6) model — the same serve_step the decode dry-run shapes lower.

Run:  PYTHONPATH=src python examples/serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import synthetic_batch
from repro.dist.train import make_decode_step, make_prefill_step
from repro.models import transformer as TF
from repro.models.params import init_params

B, PROMPT, GEN = 4, 32, 12


def serve(arch: str):
    cfg = get_config(arch).reduced()
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, B, PROMPT, seed=1)
    batch.pop("labels")
    prefill = jax.jit(make_prefill_step(cfg, PROMPT + GEN, flags))
    decode = jax.jit(make_decode_step(cfg, flags), donate_argnums=(1,))
    tok, cache = prefill(params, batch)
    outs = [np.asarray(tok)]
    for _ in range(GEN - 1):
        tok, cache = decode(params, cache, tok[:, None])
        outs.append(np.asarray(tok))
    gen = np.stack(outs, 1)
    print(f"{arch:<22} generated {gen.shape} tokens; "
          f"seq0: {gen[0][:8].tolist()}...")
    assert np.isfinite(gen).all()


def main():
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "zamba2-7b", "rwkv6-1.6b"):
        serve(arch)
    print("\n4 architecture families served through the same API.")


if __name__ == "__main__":
    main()
