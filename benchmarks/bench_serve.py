"""Serving-path acceptance + perf: continuous batching on the paged cache.

Replays deterministic request traces (mixed prompt/generation lengths,
staggered arrivals) through `repro.serve` and emits:

  * ``accept/serve_paged_parity`` — every request decoded by the continuous
    engine on the paged KV cache must be BITWISE equal to the legacy
    dense-cache B=1 loop for the same prompt (fp32 kv, prompt lengths
    multiples of the page size, matched gather width — the conditions under
    which the paged gather is a pure reshape of the dense cache),
  * ``accept/serve_continuous_vs_static`` — same trace, useful tokens/s of
    the continuous pump vs static arrival-order batches (each static batch
    decodes until its *longest* request finishes); continuous must win on a
    mixed-generation-length trace at matched outputs,
  * ``serve/p50_latency_steps`` / ``serve/p99_latency_steps`` (+ static
    variants) — per-request latency in virtual decode steps,
  * ``accept/serve_replica_staleness`` — serving from a `ParamReplica` while
    training publishes every step: observed staleness must stay within
    ``tau_serve`` (the elastic-consistency bound applied to serving),
  * ``serve/paged_decode_us`` vs ``serve/dense_decode_us`` — one decode
    step, paged engine vs dense-cache legacy step at the same batch width.

Everything runs in-process on the default host device; ``BENCH_SIM_SMOKE=1``
shrinks the traces for the CI fast lane.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, timed

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))
ARCH = "qwen3-1.7b-smoke"
PS = 8                                      # page size
SLOTS = 2 if SMOKE else 4                   # engine request slots
TAU_SERVE = 3


def _ctx():
    """Shared model context (params in fp32-kv flags for bitwise parity)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as TF
    from repro.models.params import init_params

    cfg = get_config(ARCH)
    flags = TF.RunFlags(remat=False, kv_cache_dtype=jnp.float32)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, flags, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=s, dtype=np.int32)
            for s in lens]


def _legacy_loop(cfg, flags, params, prompt, n_new, max_len):
    """B=1 dense-cache greedy loop; returns (n_new,) numpy tokens."""
    import jax
    import jax.numpy as jnp

    from repro.dist.train import make_decode_step, make_prefill_step

    prefill = jax.jit(make_prefill_step(cfg, max_len, flags))
    decode = jax.jit(make_decode_step(cfg, flags))
    tok, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    out = [tok]
    for _ in range(n_new - 1):
        tok, cache = decode(params, cache, tok[:, None])
        out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))[0]


def _run_trace(engine, trace, queue_limit=64):
    from repro.serve import ContinuousScheduler

    sched = ContinuousScheduler(engine, queue_limit=queue_limit)
    toks = sched.run(trace)
    return sched, toks


def _parity_rows(cfg, flags, params):
    from repro.serve import PagedCacheConfig, Request, StepEngine

    lens = [8, 16] if SMOKE else [8, 16, 8, 24]
    gens = [4, 6] if SMOKE else [6, 10, 4, 8]
    arrivals = [0, 0] if SMOKE else [0, 0, 1, 3]
    n_table = max((s + g + PS - 1) // PS for s, g in zip(lens, gens))
    max_len = n_table * PS                  # matched gather width
    pcfg = PagedCacheConfig(page_size=PS, num_pages=SLOTS * n_table,
                            max_requests=SLOTS, max_pages_per_seq=n_table)
    engine = StepEngine(cfg, params, pcfg, flags)
    prompts = _prompts(cfg, lens)
    trace = [Request(rid=i, prompt=p, max_new=g, arrival=a)
             for i, (p, g, a) in enumerate(zip(prompts, gens, arrivals))]

    t0 = time.perf_counter()
    sched, toks = _run_trace(engine, trace)
    dt = time.perf_counter() - t0
    engine.alloc.check()
    assert engine.alloc.n_free == pcfg.num_pages, "page leak after drain"

    bad = 0
    for i, (p, g) in enumerate(zip(prompts, gens)):
        ref = _legacy_loop(cfg, flags, params, p, g, max_len)
        bad += int(np.sum(toks[i] != ref))
    ok = "OK" if bad == 0 else "FAIL"
    return [row("accept/serve_paged_parity", dt * 1e6 / max(sched.clock, 1),
                f"mismatched_tokens={bad} over {len(lens)} mixed-length "
                f"staggered requests "
                f"rejected_frac={sched.stats()['rejected_frac']:.3f}: {ok}")]


def _throughput_rows(cfg, flags, params):
    from repro.serve import PagedCacheConfig, Request, StepEngine

    # interleaved long/short generations: the worst case for static
    # batching, whose every batch decodes until its longest member finishes
    prompt_len = PS
    gens = ([20, 2] * (2 * SLOTS))[:4 * SLOTS] if SMOKE \
        else ([28, 3] * (2 * SLOTS))[:4 * SLOTS]
    n_req = len(gens)
    n_table = (prompt_len + max(gens) + PS - 1) // PS
    prompts = _prompts(cfg, [prompt_len] * n_req, seed=1)
    useful = sum(gens)

    # ONE engine serves both policies: the comparison isolates the
    # admission policy (gang-scheduled batches vs per-step continuous) on
    # identical kernels, prefill path and cache layout.  (Dense-vs-paged is
    # the parity + decode-us rows' job.)
    pcfg = PagedCacheConfig(page_size=PS, num_pages=SLOTS * n_table,
                            max_requests=SLOTS, max_pages_per_seq=n_table)
    engine = StepEngine(cfg, params, pcfg, flags)

    def static_run():
        """Arrival-order batches of SLOTS; a batch is admitted together,
        decoded until its LONGEST request finishes, evicted together."""
        latencies, clock = [], 0
        for b0 in range(0, n_req, SLOTS):
            bg = gens[b0:b0 + SLOTS]
            for j, g in enumerate(bg):
                engine.start(b0 + j, prompts[b0 + j], g)
            steps = max(bg)                 # tokens incl. the prefill one
            for _ in range(steps - 1):
                engine.step()
            engine.tokens.block_until_ready()
            for j, g in enumerate(bg):
                engine.finish(b0 + j)
                latencies.append(clock + g)  # streamed: own last token
            clock += steps
        return latencies, clock

    static_run()                            # compile
    t0 = time.perf_counter()
    static_lat, static_steps = static_run()
    static_s = time.perf_counter() - t0

    # -- continuous: same requests, same engine, all arriving at step 0
    trace = [Request(rid=i, prompt=p, max_new=g, arrival=0)
             for i, (p, g) in enumerate(zip(prompts, gens))]
    _run_trace(engine, trace)               # warm scheduler path
    t0 = time.perf_counter()
    sched, _ = _run_trace(engine, trace)
    cont_s = time.perf_counter() - t0

    cont_tps = useful / cont_s
    stat_tps = useful / static_s
    ok = "OK" if cont_tps > stat_tps else "FAIL"
    p50, p99 = sched.latency_percentiles()
    sp50, sp99 = (float(np.percentile(static_lat, 50)),
                  float(np.percentile(static_lat, 99)))
    return [
        row("accept/serve_continuous_vs_static", cont_s * 1e6,
            f"continuous={cont_tps:.1f} static={stat_tps:.1f} tok/s "
            f"({sched.clock} vs {static_steps} steps, {useful} useful "
            f"tokens, rejected_frac="
            f"{sched.stats()['rejected_frac']:.3f}): {ok}"),
        row("serve/p50_latency_steps", p50, f"continuous, {n_req} requests"),
        row("serve/p99_latency_steps", p99, f"continuous, {n_req} requests"),
        row("serve/static_p50_latency_steps", sp50,
            f"static batches of {SLOTS}"),
        row("serve/static_p99_latency_steps", sp99,
            f"static batches of {SLOTS}"),
    ]


def _replica_rows(cfg, flags, params):
    from repro.serve import (PagedCacheConfig, ParamReplica, Request,
                             StepEngine)
    from repro.serve.scheduler import ContinuousScheduler

    gens = [4, 8] if SMOKE else [6, 14]
    n_table = (PS + max(gens) + PS - 1) // PS
    pcfg = PagedCacheConfig(page_size=PS, num_pages=2 * n_table,
                            max_requests=2, max_pages_per_seq=n_table)
    replica = ParamReplica(params, TAU_SERVE, schedule="straggler", seed=3)
    engine = StepEngine(cfg, params, pcfg, flags, replica=replica)
    sched = ContinuousScheduler(engine)
    for i, (p, g) in enumerate(zip(_prompts(cfg, [PS, PS], seed=2), gens)):
        sched.submit(Request(rid=i, prompt=p, max_new=g, arrival=0))

    version, seen = 0, []
    t0 = time.perf_counter()
    while sched.queue or sched._live or sched.clock == 0:
        version += 1
        replica.publish(params, version)    # training advances every step
        if sched.clock % 2 == 0:
            replica.refresh()
        sched.step()
        seen.append(replica.staleness)
        if sched.clock > 1000:
            raise RuntimeError("replica serve loop did not drain")
    dt = time.perf_counter() - t0
    sched.drain()
    worst = max(seen)
    ok = "OK" if worst <= TAU_SERVE else "FAIL"
    return [row("accept/serve_replica_staleness", dt * 1e6 / len(seen),
                f"max_staleness={worst} tau_serve={TAU_SERVE} over "
                f"{version} published versions "
                f"rejected_frac={sched.stats()['rejected_frac']:.3f}: {ok}")]


def _decode_step_rows(cfg, flags, params):
    import jax
    import jax.numpy as jnp

    from repro.dist.train import make_decode_step, make_prefill_step
    from repro.serve import PagedCacheConfig, Request, StepEngine

    steps = 8 if SMOKE else 32
    n_table = (PS + steps + 2 + PS - 1) // PS
    max_len = n_table * PS
    pcfg = PagedCacheConfig(page_size=PS, num_pages=SLOTS * n_table,
                            max_requests=SLOTS, max_pages_per_seq=n_table)
    engine = StepEngine(cfg, params, pcfg, flags)
    for i, p in enumerate(_prompts(cfg, [PS] * SLOTS, seed=4)):
        engine.start(i, p, steps + 2)

    def paged_step():
        return engine.step().block_until_ready()

    _, paged_us = timed(paged_step, warmup=2, iters=min(4, steps))

    prefill = jax.jit(make_prefill_step(cfg, max_len, flags))
    decode = jax.jit(make_decode_step(cfg, flags))
    batch = {"tokens": jnp.asarray(np.stack(_prompts(
        cfg, [PS] * SLOTS, seed=4)))}
    tok, cache = prefill(params, batch)
    state = {"tok": tok, "cache": cache}

    def dense_step():
        t, c = decode(params, state["cache"], state["tok"][:, None])
        state["tok"], state["cache"] = t, c
        return t.block_until_ready()

    _, dense_us = timed(dense_step, warmup=2, iters=min(4, steps))
    for i in range(SLOTS):
        engine.finish(i)
    engine.alloc.check()
    return [
        row("serve/paged_decode_us", paged_us,
            f"{SLOTS}-slot paged engine step"),
        row("serve/dense_decode_us", dense_us,
            f"B={SLOTS} dense-cache legacy step, max_len={max_len}"),
    ]


def run():
    cfg, flags, params = _ctx()
    rows = []
    rows += _parity_rows(cfg, flags, params)
    rows += _throughput_rows(cfg, flags, params)
    rows += _replica_rows(cfg, flags, params)
    rows += _decode_step_rows(cfg, flags, params)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
