"""Lemma 6: convergence slowdown is linear in B^2 under the adversarial
oracle — measured final distance vs B, plus the iterations-to-epsilon
scaling."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core import theory
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate

P, T, ALPHA, DIM = 4, 600, 0.02, 32


def run():
    prob = Quadratic(dim=DIM, cond=8.0, sigma=0.3, seed=0)
    x0 = np.ones(DIM, np.float32) * 2.0
    rows = []
    finals = {}
    for b in (0.0, 10.0, 40.0, 80.0):
        res, us = timed(lambda bb=b: simulate(
            prob, Relaxation("adversarial", B_adv=bb), P, ALPHA, T, seed=7,
            x0=x0), iters=1)
        d2 = float(np.sum((res.x_final - np.asarray(prob.x_star)) ** 2))
        finals[b] = d2
        rows.append(row(f"lemma6/B{b:g}", us,
                        f"final_dist2={d2:.5f};"
                        f"T_lower_bound(eps=0.1)="
                        f"{theory.lemma6_iters(max(b, 1e-9), 0.1):.0f}"))
    # monotonicity check in derived field
    mono = finals[0.0] < finals[10.0] < finals[40.0] < finals[80.0]
    rows.append(row("lemma6/monotone_in_B2", 0.0,
                    f"{'ok' if mono else 'VIOLATION'}"))
    return rows
