"""Figure 1 (right) / Figure 3 (left): accuracy vs (modelled) time.

No wall-clock GPUs here, so time is modelled per iteration as
    t_iter = t_compute + wire_bytes / link_bw
with wire bytes counted exactly per strategy (what each worker puts on the
wire per step: dense all-reduce vs top-k payloads vs deferred buckets). The
benchmark reports modelled time-to-target-loss, and the wire-byte savings —
the quantity the paper's ~20-30% speedup comes from.

All strategies run through ONE ``simulate_grid`` call (one compiled program
per strategy group) instead of the per-strategy Python loop of `simulate`
calls this bench used to run.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core import compression as C
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate_grid

P, T, ALPHA = 8, 800, 0.08
LINK_BW = 50e9          # bytes/s per worker link (ICI-class)
T_COMPUTE = 0.11        # modelled fwd+bwd per iteration at production scale
#                         (qwen3-1.7b train_4k: ~72 TFLOP/dev / 197 TFLOP/s
#                          x ~3 latency factor, from the dry-run)
WIRE_DIM = 1_720_565_760 // 16  # params per model shard (qwen3-1.7b / 16):
#                         convergence comes from the simulator; wire volume
#                         is modelled at the production workload the paper's
#                         scheduler would actually serve.
TARGET_FACTOR = 0.45    # target = factor * initial loss


def _wire_bytes_per_step(d: int, strategy: str, **kw) -> float:
    if strategy in ("sync", "elastic_variance"):
        return 2 * 4 * d                      # ring all-reduce, f32
    if strategy == "topk":
        k = int(d * kw["ratio"])
        return P * 8 * k                      # gathered (val, idx) pairs
    if strategy == "onebit":
        return P * (d / 8 + 8)
    if strategy == "elastic_norm":
        return 2 * 4 * d * kw["beta_frac"]    # deferred fraction skipped
    raise ValueError(strategy)


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    d = WIRE_DIM
    cases = [
        ("exact", Relaxation("sync"), dict(strategy="sync")),
        ("elastic_norm_b08", Relaxation("elastic_norm", beta=0.8),
         dict(strategy="elastic_norm", beta_frac=0.8)),
        ("topk_ef_1of16", Relaxation(
            "ef_comp", compressor=C.topk_compressor(1 / 16)),
         dict(strategy="topk", ratio=1 / 16)),
        ("onebit_ef", Relaxation("ef_comp",
                                 compressor=C.onebit_compressor()),
         dict(strategy="onebit")),
        ("elastic_variance", Relaxation("elastic_variance", drop_prob=0.3),
         dict(strategy="elastic_variance")),
    ]

    grid, us_grid = timed(lambda: simulate_grid(
        mlp, [c[1] for c in cases], P, ALPHA, T, seeds=(4,), x0=x0),
        iters=1)
    # common target from the exact run
    target = grid[(0, 0, P, 0, 4)].losses[0] * TARGET_FACTOR

    rows = [row("fig1_right/grid_total", us_grid, f"cases={len(cases)}")]
    base_time = None
    us = us_grid / len(cases)
    for ic, (name, relax, wire_kw) in enumerate(cases):
        res = grid[(0, ic, P, 0, 4)]
        hit = np.argmax(res.losses < target)
        steps = (int(hit) if res.losses[hit] < target else len(res.losses)) \
            * res.record_every
        wire = _wire_bytes_per_step(d, **wire_kw)
        t_iter = T_COMPUTE + wire / LINK_BW
        t_total = steps * t_iter
        if base_time is None:
            base_time = t_total
        rows.append(row(
            f"fig1_right/{name}", us,
            f"steps_to_target={steps};wire_B_per_step={wire:.0f};"
            f"modelled_s={t_total * 1e3:.2f}ms;"
            f"speedup_vs_exact={base_time / max(t_total, 1e-12):.2f}x"))
    return rows
