"""Cluster co-simulation bench: time-to-loss vs steps-to-loss.

Runs the `repro.cluster` co-simulation on the named cluster presets and
emits one row per (cluster, candidate) plus the two acceptance gates:

  accept/cosim_timetoloss  on the uniform pod relaxation buys nothing
                           (sync within 10% of the best wall-clock); on
                           the straggler-heavy fleet the steps-to-loss
                           and time-to-loss winners DIFFER and the
                           wall-clock winner is a relaxed strategy >30%
                           faster than sync — the paper's pitch,
                           measured end to end
  accept/cosim_tau_valid   every measured tau(t, worker) table the event
                           loop emitted satisfies the delivery contract
                           (`core.delivery.validate_tau_table`), incl.
                           DROPPED rows under the preemptible trace

``BENCH_SIM_SMOKE=1`` shrinks the horizon for CI fast lanes.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import row, timed

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))


def run():
    from repro.cluster import (preset, rank_candidates, simulate_cluster,
                               winners)
    from repro.core.delivery import DROPPED, validate_tau_table

    t_len = 240 if SMOKE else 600
    p = 4
    rows = []
    flips = {}
    all_results = {}
    tau_ok, tau_checked = True, 0

    for shape in ("uniform", "straggler_heavy"):
        spec = preset(shape, p=p, steps=t_len)
        results, runs = rank_candidates(spec, t_len=t_len)
        w = winners(results)
        flips[shape] = w
        all_results[shape] = results
        for r in results:
            rows.append(row(
                f"cosim/{shape}/{r.candidate}", r.step_s * 1e6,
                f"steps={r.steps_to_loss:.0f};"
                f"time_s={r.time_to_loss:.2f};"
                f"wire_B={r.wire_bytes:.0f};dropped={r.dropped}"))
        rows.append(row(f"cosim/{shape}/winner", 0.0,
                        f"steps={w['steps']};time={w['time']}"))
        for cr in runs.values():
            try:
                validate_tau_table(cr.taus, cr.tau_max)
                tau_checked += 1
            except ValueError:
                tau_ok = False

    # preemption: DROPPED rows must appear AND still validate
    pre = preset("preemptible", p=p, steps=t_len)
    pre_run = simulate_cluster(pre, t_len, 4, 4e8, 4.7e6)
    n_dropped = int(np.count_nonzero(pre_run.taus == DROPPED))
    try:
        validate_tau_table(pre_run.taus, pre_run.tau_max)
        tau_checked += 1
    except ValueError:
        tau_ok = False
    rows.append(row("cosim/preemptible/dropped", 0.0,
                    f"dropped={n_dropped};hist={pre_run.tau_histogram()}"))

    # event-loop throughput (the jitted scan, post-compile)
    _, us = timed(lambda: simulate_cluster(pre, t_len, 4, 4e8, 4.7e6))
    rows.append(row("cluster/event_loop_us", us,
                    f"T={t_len};p={p};steps_per_s={t_len / (us * 1e-6):.0f}"))

    # The demonstration (margin-gated so noise-floor step ties can't flip
    # the verdict): on the uniform pod relaxation buys ~nothing — sync's
    # wall-clock is within 10% of the best; on the straggler-heavy fleet
    # the steps winner and the time winner DIFFER and the time winner is
    # a relaxed strategy beating sync's wall-clock by >30%.
    uni, strag = flips["uniform"], flips["straggler_heavy"]
    times = {s: {r.candidate: r.time_to_loss for r in all_results[s]}
             for s in all_results}
    uni_ok = times["uniform"]["sync"] <= 1.10 * min(
        times["uniform"].values())
    s_t = times["straggler_heavy"]
    strag_ok = (strag["steps"] != strag["time"]
                and strag["time"] != "sync"
                and s_t[strag["time"]] < 0.7 * s_t["sync"])
    flip_ok = uni_ok and strag_ok
    rows.append(row(
        "accept/cosim_timetoloss", 0.0,
        f"{'OK' if flip_ok else 'FAIL'}:uniform={uni['steps']}/{uni['time']};"
        f"straggler={strag['steps']}/{strag['time']};"
        f"speedup={s_t['sync'] / s_t[strag['time']]:.2f}x"))
    valid_ok = tau_ok and n_dropped > 0
    rows.append(row(
        "accept/cosim_tau_valid", 0.0,
        f"{'OK' if valid_ok else 'FAIL'}:tables={tau_checked};"
        f"dropped={n_dropped}"))
    return rows
