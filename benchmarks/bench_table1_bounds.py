"""Table 1: measured elastic-consistency constant B_hat vs the paper's
theoretical bound, per relaxation, on the strongly-convex testbed."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core import compression as C, theory
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate, simulate_shared_memory

P, T, ALPHA, DIM = 8, 600, 0.02, 32


def run():
    prob = Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)
    x0 = np.ones(DIM, np.float32) * 2.0
    r2 = float(np.sum((x0 - np.asarray(prob.x_star)) ** 2)) * 1.5
    m2 = prob.m2_estimate(r2)
    s2 = prob.sigma2

    cases = [
        ("sync", Relaxation("sync"), 0.0),
        ("crash_f3", Relaxation("crash", f=3), theory.b_crash_m(P, 3, m2)),
        ("crash_subst_f3", Relaxation("crash_subst", f=3),
         theory.b_crash_variance(P, 3, s2)),
        ("omission_f6", Relaxation("omission", f=6, drop_prob=0.2),
         theory.b_crash_m(P, 6, m2)),
        ("async_tau2", Relaxation("async", tau_max=2),
         theory.b_async_mp(P, 2, m2)),
        ("topk_ef_25pct", Relaxation("ef_comp",
                                     compressor=C.topk_compressor(0.25)),
         theory.b_ef_compression(C.topk_gamma(DIM, DIM // 4), m2)),
        ("onebit_ef", Relaxation("ef_comp", compressor=C.onebit_compressor()),
         theory.b_ef_compression(C.onebit_gamma(DIM), m2)),
        ("elastic_norm_b08", Relaxation("elastic_norm", beta=0.8), None),
        ("elastic_variance", Relaxation("elastic_variance", drop_prob=0.3),
         theory.b_elastic_scheduler_variance(s2)),
    ]

    rows = []
    for name, relax, bound in cases:
        res, us = timed(lambda: simulate(prob, relax, P, ALPHA, T, seed=3,
                                         x0=x0), iters=1)
        ok = "na" if bound is None else ("ok" if res.b_hat <= bound * 1.05
                                         else "VIOLATION")
        rows.append(row(
            f"table1/{name}", us,
            f"B_hat={res.b_hat:.2f};B_theory="
            f"{bound if bound is not None else float('nan'):.2f};{ok};"
            f"loss_end={res.losses[-1]:.4f}"))

    res, us = timed(lambda: simulate_shared_memory(
        prob, P, 0.005, T, tau_max=3, seed=3, x0=x0), iters=1)
    b = theory.b_shared_memory(DIM, 3, m2)
    rows.append(row("table1/shared_memory_tau3", us,
                    f"B_hat={res.b_hat:.2f};B_theory={b:.2f};"
                    f"{'ok' if res.b_hat <= b else 'VIOLATION'};"
                    f"loss_end={res.losses[-1]:.4f}"))
    return rows
