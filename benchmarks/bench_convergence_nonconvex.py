"""Theorems 2/3: non-convex convergence — measured min_t ||grad f(x_t)||^2
against the theorem RHS across a grid of T, under the prescribed
alpha = sqrt(p/T)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core import theory
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate

P = 8


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    pc = mlp.constants(x0)
    rows = []
    for T in (200, 400, 800):
        alpha = (P / T) ** 0.5 * 0.2  # scaled: L-estimate is conservative
        res, us = timed(lambda a=alpha, t=T: simulate(
            mlp, Relaxation("elastic_variance", drop_prob=0.3), P, a, t,
            seed=4, x0=x0, record_every=5), iters=1)
        measured = float(np.min(res.grad_norms2))
        b = theory.b_elastic_scheduler_variance(pc.sigma2)
        rhs = theory.thm3_rhs(pc, b, T, P)
        rows.append(row(
            f"thm3_nonconvex/T{T}", us,
            f"min_grad2={measured:.4f};thm3_rhs={rhs:.4f};"
            f"{'ok' if measured <= rhs else 'VIOLATION'}"))
    return rows
