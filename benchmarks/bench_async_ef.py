"""EF-may-not-help under emulated asynchrony, at real-model scale.

The paper's headline empirical observation is that error feedback — which
provably and practically rescues *synchronous* sparsified SGD — may stop
helping once gradients are also stale.  `repro.dist.async_engine` makes
that testable on the real models: this bench trains a small dense config
on a forced 2-device host mesh under the bounded-staleness engine with
top-k sparsification, EF on vs off, for tau_max in {0, 4, 16}, and emits
one accept row per tau comparing final losses.

Also emitted:
  * ``accept/async_tau0_parity`` — the tau_max=0 async path vs the
    synchronous `exact` strategy (`make_elastic_train_step`): max abs loss
    difference over the run must be <= 1e-5 (it is bitwise-0 in practice —
    the delay ring at capacity 1 is deposit-then-take of the same slot),
  * ``async/steps_per_s`` vs ``async/exact_steps_per_s`` — the emulated
    asynchrony must not give up the synchronous hot-path speed.

The training loops run in ONE subprocess (XLA_FLAGS must force the
2-device host platform before jax initializes, which cannot be done from
inside the already-initialized bench harness process); the child prints
``BENCHROW|name|us|derived`` lines that the parent converts to rows.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from benchmarks.common import row

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))
STEPS = 12 if SMOKE else 40
TAUS = (0, 4, 16)


def _child() -> None:
    import jax
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.configs import get_config
    from repro.core.scheduler import SyncConfig
    from repro.data.pipeline import SyntheticLMDataset
    from repro.dist import sharding as SH
    from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                         make_async_train_step)
    from repro.dist.train import init_dist_sync_state, make_elastic_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as TF
    from repro.models.params import init_params, param_specs
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b").reduced()          # small dense config
    mesh = make_host_mesh()
    assert SH.axis_sizes(mesh)["data"] == 2, dict(mesh.shape)
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params0 = init_params(defs, jax.random.PRNGKey(0))
    opt = momentum(0.02, 0.9)
    data = SyntheticLMDataset(cfg.vocab_size, 32, 8, seed=0)

    def shard_batch(b):
        return {k: jax.device_put(
                    v, NamedSharding(mesh, SH.batch_spec(mesh, v.shape[0])))
                for k, v in b.items()}

    batches = [shard_batch(data.batch(t)) for t in range(STEPS)]

    def train(step_fn, mkstate):
        params, opt_state = params0, opt.init(params0)
        # two discarded warmup steps so the steps/s rows time the
        # overlapped steady state: the first pays compile, the second runs
        # the compiled program with PRIMED delivery buffers (the overlap
        # engine's first post-compile step still touches all-zero payload
        # rings; the second is the shape every later step has).  The timed
        # loop then restarts from fresh state so the loss trajectory is
        # unpolluted — per-step cost does not depend on ring contents.
        # (The delivery state is donated — a training loop reassigns it
        # every step — so the restart also replaces the consumed buffers.)
        wp, wo, ws, _ = step_fn(params, opt_state, mkstate(), batches[0])
        jax.block_until_ready(step_fn(wp, wo, ws, batches[1 % STEPS]))
        del wp, wo, ws
        state = mkstate()
        losses = []
        t0 = time.perf_counter()
        for b in batches:
            params, opt_state, state, metrics = step_fn(
                params, opt_state, state, b)
            losses.append(float(metrics["loss"]))
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        return losses, float(np.mean(losses[-min(10, STEPS):])), dt

    def emit(name, us, derived):
        print(f"BENCHROW|{name}|{us:.1f}|{derived}", flush=True)

    # synchronous exact baseline (shard_map pmean — the apples-to-apples
    # reference: identical program structure, delay rings removed)
    scfg = SyncConfig(strategy="exact", axis_names=("data",))
    estep = jax.jit(make_elastic_train_step(cfg, opt, mesh, scfg, pspecs,
                                            flags), donate_argnums=(2,))
    exact_losses, exact_final, exact_dt = train(
        lambda p, o, s, b: estep(p, o, s, b),
        lambda: init_dist_sync_state(scfg, mesh, params0))
    emit("async/exact_steps_per_s", exact_dt / STEPS * 1e6,
         f"{STEPS / exact_dt:.1f} steps/s (sync exact baseline)")

    def async_run(tau_max, compressor, ef, seed=0, overlap=True, reps=1):
        # track_gap off: the steps/s rows compare the engine's hot path
        # at exactly its configured wire volume.  reps > 1 re-runs the
        # timed loop on the SAME compiled step and keeps the best dt —
        # the wall-clock gates compare ~100ms/step loops, where one
        # scheduler hiccup in a single sample swamps a 20% margin.
        acfg = AsyncConfig(tau_max=tau_max, schedule="uniform",
                           compressor=compressor, error_feedback=ef,
                           topk_ratio=1 / 8, horizon=STEPS, seed=seed,
                           track_gap=False, overlap=overlap)
        astep = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                              flags), donate_argnums=(2,))
        mkstate = lambda: init_async_state(
            acfg, mesh, params0, pspecs if acfg.fused else None)
        losses, final, dt = train(astep, mkstate)
        for _ in range(reps - 1):
            dt = min(dt, train(astep, mkstate)[2])
        return losses, final, dt

    # tau_max=0 parity: bounded-delay delivery with a capacity-1 ring IS
    # the synchronous step
    a_losses, _, a_dt = async_run(0, "none", True)
    diff = max(abs(a - b) for a, b in zip(exact_losses, a_losses))
    status = "OK" if diff <= 1e-5 else "FAIL"
    emit("accept/async_tau0_parity", a_dt / STEPS * 1e6,
         f"max|dloss|={diff:.2e} <=1e-5 vs sync exact: {status}")
    emit("async/steps_per_s", a_dt / STEPS * 1e6,
         f"{STEPS / a_dt:.1f} steps/s (tau_max=0; exact base "
         f"{STEPS / exact_dt:.1f})")

    # EF vs no-EF under growing staleness (top-k sparsification)
    for tau in TAUS:
        # train() already excludes compile (warmup steps), so time the rows
        # from its returned dts, not an outer wall clock around jit builds
        _, f_ef, dt_ef = async_run(tau, "topk", True)
        _, f_noef, dt_noef = async_run(tau, "topk", False)
        emit(f"accept/async_ef_tau{tau}", (dt_ef + dt_noef) * 1e6 / (2 * STEPS),
             f"final loss ef={f_ef:.4f} noef={f_noef:.4f} "
             f"ef-noef={f_ef - f_noef:+.4f} (tau_max={tau})")

    # wall-clock speedup gate: the fused overlapped engine vs the SAME
    # configuration with overlap=False — the synchronous-wire program (the
    # compressed payload densifies into the ring and pays the full dense
    # pmean, exactly the sync all-reduce volume).  The two walk the same
    # trajectory step for step (tests/test_dist_parity.py), so final loss
    # is matched by construction and the comparison isolates what the
    # fused compress-then-reduce buys in wall-clock.  Sync exact steps/s
    # is printed alongside for scale.
    for tau in (4, 16):
        _, f_fused, dt_fused = async_run(tau, "topk", True, seed=2, reps=5)
        _, f_dens, dt_dens = async_run(tau, "topk", True, seed=2,
                                       overlap=False, reps=5)
        sps_f, sps_d = STEPS / dt_fused, STEPS / dt_dens
        matched = abs(f_fused - f_dens) <= 1e-4
        status = "OK" if (sps_f > sps_d and matched) else "FAIL"
        emit(f"accept/async_speedup_tau{tau}", dt_fused / STEPS * 1e6,
             f"fused {sps_f:.1f} vs sync-wire {sps_d:.1f} steps/s "
             f"(x{sps_f / sps_d:.2f}; sync exact {STEPS / exact_dt:.1f}) "
             f"final loss fused={f_fused:.4f} dens={f_dens:.4f} "
             f"matched={matched}: {status}")


def run() -> list:
    if "--child" in sys.argv:
        raise RuntimeError("child mode is a __main__ entry, not a bench run")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_async_ef", "--child"],
        env=env, capture_output=True, text=True, timeout=3600,
        cwd=os.path.dirname(src))
    if r.returncode != 0:
        raise RuntimeError(
            f"bench_async_ef child failed:\n{r.stdout[-2000:]}\n"
            f"{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("BENCHROW|"):
            _, name, us, derived = line.split("|", 3)
            rows.append(row(name, float(us), derived))
    if not rows:
        raise RuntimeError(f"no BENCHROW output:\n{r.stdout[-2000:]}")
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        from benchmarks.common import print_rows
        print_rows(run())
