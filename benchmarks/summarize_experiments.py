"""Fill EXPERIMENTS.md's <!-- DRYRUN_SUMMARY --> and <!-- ROOFLINE_SUMMARY -->
placeholders from the dry-run artifacts (idempotent: regenerates the blocks).

Usage: PYTHONPATH=src python -m benchmarks.summarize_experiments
"""
from __future__ import annotations

import glob
import json
import os
import re
import warnings

from benchmarks.bench_roofline import analyze_record, write_markdown

DRYRUN_DIR = "experiments/dryrun"
EXP = "EXPERIMENTS.md"
ROOFLINE_MD = "experiments/roofline.md"


def _read_artifact(path: str) -> dict | None:
    """Torn/corrupt artifacts are warned about and skipped, same posture
    as `bench_roofline.load_all`."""
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        warnings.warn(f"skipping unreadable dryrun artifact {path}: {e}")
        return None


def load(mesh: str, sync: str = "exact",
         dryrun_dir: str = DRYRUN_DIR) -> dict:
    recs = {}
    for p in sorted(glob.glob(
            os.path.join(dryrun_dir, f"*__{mesh}__{sync}.json"))):
        r = _read_artifact(p)
        if r is not None:
            recs[(r["arch"], r["shape"])] = r
    return recs


def dryrun_block(dryrun_dir: str = DRYRUN_DIR) -> str:
    single = load("single", dryrun_dir=dryrun_dir)
    multi = load("multi", dryrun_dir=dryrun_dir)
    lines = ["", "### Per-pair dry-run record (single-pod 16x16 | "
             "multi-pod 2x16x16)", "",
             "| arch | shape | single: status / mem GB / compile s | "
             "multi: status / compile s |", "|---|---|---|---|"]
    n_ok = n_skip = n_fail = 0
    for (arch, shape), r in sorted(single.items()):
        m = multi.get((arch, shape), {})
        if r["status"] == "ok":
            n_ok += 1
            s1 = (f"ok / {r['memory']['peak_per_device_gb']:.1f} / "
                  f"{r.get('compile_s', '?')}")
        elif r["status"] == "skipped":
            n_skip += 1
            s1 = "skipped (sub-quadratic gate)"
        else:
            n_fail += 1
            s1 = "FAILED"
        if m.get("status") == "ok":
            s2 = f"ok / {m.get('compile_s', '?')}"
        elif m.get("status") == "skipped":
            s2 = "skipped"
        else:
            s2 = m.get("status", "-")
        lines.append(f"| {arch} | {shape} | {s1} | {s2} |")
    lines.append("")
    lines.append(f"Totals: {n_ok} ok, {n_skip} skipped "
                 f"(documented long_500k gates), {n_fail} failed.")
    lines.append("")
    return "\n".join(lines)


def roofline_block(dryrun_dir: str = DRYRUN_DIR,
                   roofline_md: str = ROOFLINE_MD) -> str:
    rows = []
    for p in sorted(glob.glob(
            os.path.join(dryrun_dir, "*__single__exact.json"))):
        rec = _read_artifact(p)
        a = analyze_record(rec) if rec is not None else None
        if a:
            rows.append(a)
    if not rows:
        return "\n(no roofline rows yet)\n"
    write_markdown(rows, roofline_md)
    lines = ["", "### Roofline terms per (arch x shape), single-pod, "
             "paper-faithful baseline", "",
             "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
             "useful | mem GB |", "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.1f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['peak_mem_gb']} |")
    from collections import Counter
    doms = Counter(r["dominant"] for r in rows)
    lines.append("")
    lines.append(f"Dominant-term distribution: {dict(doms)}. "
                 "One-line diagnosis per row lives in experiments/roofline.md;"
                 " §Perf below iterates the three selected pairs.")
    lines.append("")
    return "\n".join(lines)


def replace_block(text: str, marker: str, content: str) -> str:
    # blocks are delimited by the marker comment; regenerate everything from
    # the marker to the next "## " heading or EOF
    pat = re.compile(rf"(<!-- {marker} -->)(.*?)(?=\n## |\Z)", re.S)
    return pat.sub(lambda m: m.group(1) + "\n" + content, text)


def summarize(exp_path: str = EXP, dryrun_dir: str = DRYRUN_DIR,
              roofline_md: str = ROOFLINE_MD) -> str:
    """Regenerate both blocks in ``exp_path`` in place; returns the new
    text (the testable core of `main`)."""
    with open(exp_path) as f:
        text = f.read()
    text = replace_block(text, "DRYRUN_SUMMARY", dryrun_block(dryrun_dir))
    text = replace_block(text, "ROOFLINE_SUMMARY",
                         roofline_block(dryrun_dir, roofline_md))
    with open(exp_path, "w") as f:
        f.write(text)
    return text


def main():
    summarize()
    print("EXPERIMENTS.md updated; experiments/roofline.md written")


if __name__ == "__main__":
    main()
