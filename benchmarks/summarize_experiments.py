"""Fill EXPERIMENTS.md's <!-- DRYRUN_SUMMARY --> and <!-- ROOFLINE_SUMMARY -->
placeholders from the dry-run artifacts (idempotent: regenerates the blocks).

Usage: PYTHONPATH=src python -m benchmarks.summarize_experiments
"""
from __future__ import annotations

import glob
import json
import os
import re

from benchmarks.bench_roofline import analyze_record, write_markdown

DRYRUN_DIR = "experiments/dryrun"
EXP = "EXPERIMENTS.md"


def load(mesh: str, sync: str = "exact"):
    recs = {}
    for p in sorted(glob.glob(f"{DRYRUN_DIR}/*__{mesh}__{sync}.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"])] = r
    return recs


def dryrun_block() -> str:
    single = load("single")
    multi = load("multi")
    lines = ["", "### Per-pair dry-run record (single-pod 16x16 | "
             "multi-pod 2x16x16)", "",
             "| arch | shape | single: status / mem GB / compile s | "
             "multi: status / compile s |", "|---|---|---|---|"]
    n_ok = n_skip = n_fail = 0
    for (arch, shape), r in sorted(single.items()):
        m = multi.get((arch, shape), {})
        if r["status"] == "ok":
            n_ok += 1
            s1 = (f"ok / {r['memory']['peak_per_device_gb']:.1f} / "
                  f"{r.get('compile_s', '?')}")
        elif r["status"] == "skipped":
            n_skip += 1
            s1 = "skipped (sub-quadratic gate)"
        else:
            n_fail += 1
            s1 = "FAILED"
        if m.get("status") == "ok":
            s2 = f"ok / {m.get('compile_s', '?')}"
        elif m.get("status") == "skipped":
            s2 = "skipped"
        else:
            s2 = m.get("status", "-")
        lines.append(f"| {arch} | {shape} | {s1} | {s2} |")
    lines.append("")
    lines.append(f"Totals: {n_ok} ok, {n_skip} skipped "
                 f"(documented long_500k gates), {n_fail} failed.")
    lines.append("")
    return "\n".join(lines)


def roofline_block() -> str:
    rows = []
    for p in sorted(glob.glob(f"{DRYRUN_DIR}/*__single__exact.json")):
        a = analyze_record(json.load(open(p)))
        if a:
            rows.append(a)
    if not rows:
        return "\n(no roofline rows yet)\n"
    write_markdown(rows, "experiments/roofline.md")
    lines = ["", "### Roofline terms per (arch x shape), single-pod, "
             "paper-faithful baseline", "",
             "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
             "useful | mem GB |", "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.1f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['peak_mem_gb']} |")
    from collections import Counter
    doms = Counter(r["dominant"] for r in rows)
    lines.append("")
    lines.append(f"Dominant-term distribution: {dict(doms)}. "
                 "One-line diagnosis per row lives in experiments/roofline.md;"
                 " §Perf below iterates the three selected pairs.")
    lines.append("")
    return "\n".join(lines)


def replace_block(text: str, marker: str, content: str) -> str:
    # blocks are delimited by the marker comment; regenerate everything from
    # the marker to the next "## " heading or EOF
    pat = re.compile(rf"(<!-- {marker} -->)(.*?)(?=\n## |\Z)", re.S)
    return pat.sub(lambda m: m.group(1) + "\n" + content, text)


def main():
    text = open(EXP).read()
    text = replace_block(text, "DRYRUN_SUMMARY", dryrun_block())
    text = replace_block(text, "ROOFLINE_SUMMARY", roofline_block())
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md updated; experiments/roofline.md written")


if __name__ == "__main__":
    main()
