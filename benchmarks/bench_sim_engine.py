"""Simulator engine micro-benchmark: ref (numpy loop) vs scan (lax.scan).

Reports steps/s for both engines across a (p, d, kind) grid plus the
scan/ref speedup, seeds/s for the vmapped multi-seed sweep, and one
``accept/*`` summary row per headline kind (best p >= 16 speedup, with an
ok/BELOW_10X marker) — the PR-over-PR perf tripwire for the tentpole claim.
`run.py` persists every row into ``BENCH_sim.json`` so the trajectory is
tracked across PRs.

Two regimes on purpose: at d = 128 the quadratic's matvec is cheap and the
grid measures pure engine overhead (the oracle pays ~1-2 ms/step in python
loops, per-step jit dispatch and device syncs); at d = 256 the dense matvec
starts to dominate *both* engines, so the ratio compresses toward the
shared compute cost — that row tracks how close the scan engine runs to
the problem's arithmetic floor.

Timing is best-of-N (`timed(..., best=True)`), not mean — engine speedups,
not machine load, are what this file tracks.  Set ``BENCH_SIM_SMOKE=1``
for a seconds-scale CI smoke grid.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate, simulate_sweep

SMOKE = bool(int(os.environ.get("BENCH_SIM_SMOKE", "0")))

KINDS = [
    ("sync", lambda: Relaxation("sync")),
    ("async", lambda: Relaxation("async", tau_max=3)),
    ("elastic_variance", lambda: Relaxation("elastic_variance",
                                            drop_prob=0.3)),
    ("elastic_norm", lambda: Relaxation("elastic_norm", beta=0.8)),
    ("crash_subst", lambda: Relaxation("crash_subst", f=3)),
]
ACCEPT_KINDS = ("sync", "async", "elastic_variance")

GRID = [(8, 64)] if SMOKE else [(8, 256), (16, 128), (16, 256), (32, 128),
                                (32, 256)]
T = 50 if SMOKE else 400
SWEEP_SEEDS = 4 if SMOKE else 16


def _steps_per_s(us: float) -> float:
    return T / (us / 1e6)


def run():
    rows = []
    probs = {}
    best = {k: 0.0 for k in ACCEPT_KINDS}    # best p>=16 speedup per kind
    for p, d in GRID:
        if d not in probs:
            probs[d] = Quadratic(dim=d, cond=8.0, sigma=1.0, seed=0)
        prob = probs[d]
        x0 = np.ones(d, np.float32)
        for name, mk in KINDS:
            relax = mk()
            # fused=False: this bench tracks the UNFUSED scan engine's
            # trajectory across PRs; bench_sim_step_kernel owns the
            # fused-vs-unfused comparison.
            _, us_ref = timed(lambda: simulate(
                prob, relax, p, 0.02, T, seed=3, x0=x0, engine="ref"),
                warmup=1, iters=2, best=True)
            _, us_scan = timed(lambda: simulate(
                prob, relax, p, 0.02, T, seed=3, x0=x0, engine="scan",
                fused=False), warmup=1, iters=3, best=True)
            speed = us_ref / us_scan
            if p >= 16 and name in ACCEPT_KINDS:
                best[name] = max(best[name], speed)
            tag = f"sim_engine/{name}_p{p}_d{d}"
            rows.append(row(f"{tag}_ref", us_ref,
                            f"steps_per_s={_steps_per_s(us_ref):.0f}"))
            rows.append(row(
                f"{tag}_scan", us_scan,
                f"steps_per_s={_steps_per_s(us_scan):.0f};"
                f"speedup_vs_ref={speed:.1f}x"))
    # vmapped multi-seed sweep: one compiled program over stacked seeds
    p, d = GRID[-1]
    prob = probs[d]
    x0 = np.ones(d, np.float32)
    relax = Relaxation("async", tau_max=3)
    seeds = list(range(SWEEP_SEEDS))
    _, us_sweep = timed(lambda: simulate_sweep(
        prob, relax, p, 0.02, T, seeds, x0=x0, fused=False),
        warmup=1, iters=3, best=True)
    _, us_one = timed(lambda: simulate(
        prob, relax, p, 0.02, T, seed=0, x0=x0, engine="scan", fused=False),
        warmup=1, iters=3, best=True)
    rows.append(row(
        f"sim_engine/sweep_async_p{p}_d{d}_x{SWEEP_SEEDS}", us_sweep,
        f"seeds_per_s={SWEEP_SEEDS / (us_sweep / 1e6):.1f};"
        f"vmap_efficiency={SWEEP_SEEDS * us_one / us_sweep:.1f}x"))
    if not SMOKE:
        for name in ACCEPT_KINDS:
            rows.append(row(
                f"accept/sim_engine_{name}_10x_p16", 0.0,
                f"best_speedup={best[name]:.1f}x;"
                + ("ok" if best[name] >= 10.0 else "BELOW_10X")))
    return rows
