"""Benchmark harness: one module per paper table/figure (+ roofline, kernel
and simulator-engine micro-benches). Prints ``name,us_per_call,derived`` CSV
and mirrors the rows into a machine-readable ``BENCH_sim.json`` (override
the path with ``BENCH_JSON``) so the perf trajectory is tracked across PRs.
The JSON maps row name -> {us_per_call, derived}, plus one ``_module_rows``
bookkeeping key so filtered re-runs can evict a module's stale rows.
"""
import json
import os
import sys
import traceback

from benchmarks.common import print_rows

MODULES = [
    "benchmarks.bench_table1_bounds",
    "benchmarks.bench_fig1_beta_accuracy",
    "benchmarks.bench_fig1_speedup",
    "benchmarks.bench_fig3_variance_bounded",
    "benchmarks.bench_convergence_nonconvex",
    "benchmarks.bench_convergence_strongly_convex",
    "benchmarks.bench_lemma6_lower_bound",
    "benchmarks.bench_sim_engine",
    "benchmarks.bench_sim_step_kernel",
    "benchmarks.bench_async_ef",
    "benchmarks.bench_kernels",
    "benchmarks.bench_serve",
    "benchmarks.bench_faults",
    "benchmarks.bench_analysis",
    "benchmarks.bench_roofline",
    "benchmarks.bench_cluster",
]

JSON_PATH = os.environ.get("BENCH_JSON", "BENCH_sim.json")


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    results: dict = {}
    module_rows: dict = {}           # module -> row names it produced
    only = sys.argv[1] if len(sys.argv) > 1 else ""
    for modname in MODULES:
        if only and only not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            print_rows(rows)
            for name, us, derived in rows:
                results[name] = {"us_per_call": us, "derived": derived}
            module_rows[modname] = [r[0] for r in rows]
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{modname},0,FAILED")
            results[modname] = {"us_per_call": 0, "derived": "FAILED"}
            module_rows[modname] = [modname]
            failed += 1
    if only and os.path.exists(JSON_PATH):
        # filtered re-run: merge into the existing record instead of
        # clobbering the other modules' perf trajectory — dropping every
        # row a re-run module produced last time, so a module that now
        # fails doesn't leave stale pre-regression numbers behind
        with open(JSON_PATH) as fh:
            merged = json.load(fh)
        prev_rows = merged.pop("_module_rows", {})
        stale = set(module_rows) | (set(prev_rows) - set(MODULES))
        for modname in stale:             # re-run + renamed/deleted modules
            for name in prev_rows.pop(modname, []):
                merged.pop(name, None)
            merged.pop(modname, None)     # old FAILED marker, if any
        merged.update(results)
        results = merged
        module_rows = {**prev_rows, **module_rows}
    results["_module_rows"] = module_rows
    with open(JSON_PATH, "w") as fh:
        json.dump(results, fh, indent=1, sort_keys=True)
        fh.write("\n")
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
