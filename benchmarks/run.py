"""Benchmark harness: one module per paper table/figure (+ roofline and
kernel micro-benches). Prints ``name,us_per_call,derived`` CSV."""
import sys
import traceback

from benchmarks.common import print_rows

MODULES = [
    "benchmarks.bench_table1_bounds",
    "benchmarks.bench_fig1_beta_accuracy",
    "benchmarks.bench_fig1_speedup",
    "benchmarks.bench_fig3_variance_bounded",
    "benchmarks.bench_convergence_nonconvex",
    "benchmarks.bench_convergence_strongly_convex",
    "benchmarks.bench_lemma6_lower_bound",
    "benchmarks.bench_kernels",
    "benchmarks.bench_roofline",
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    only = sys.argv[1] if len(sys.argv) > 1 else ""
    for modname in MODULES:
        if only and only not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            print_rows(mod.run())
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{modname},0,FAILED")
            failed += 1
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
