"""Fused simulator-step micro-benchmark: unfused scan step vs the fused
`kernels/sim_step` fast path, plus the batched multi-(p, d) grid API vs
the Python loop of per-case sweeps it replaces.

The d >= 256 rows are the point: there both engines used to sit on a
shared dense-matvec floor (ROADMAP item), and the fused step lifts it —
the row-major gradient matmul, the single stacked delivery matmul and the
precomputed delivery tensors cut both the FLOPs and the per-step op count.
``accept/sim_step_fused_{kind}`` rows assert the >= 2x steps/s target on
``sync`` and ``crash_subst`` (best over the d >= 256 grid, mirroring the
engine bench's accept convention).  Set ``BENCH_SIM_SMOKE=1`` for a
seconds-scale CI smoke grid.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate, simulate_grid, simulate_sweep

SMOKE = bool(int(os.environ.get("BENCH_SIM_SMOKE", "0")))

KINDS = [
    ("sync", lambda: Relaxation("sync")),
    ("crash_subst", lambda: Relaxation("crash_subst", f=3)),
    ("elastic_variance", lambda: Relaxation("elastic_variance",
                                            drop_prob=0.3)),
]
ACCEPT_KINDS = ("sync", "crash_subst")
TARGET = 2.0

GRID = [(8, 64)] if SMOKE else [(16, 256), (16, 512)]
T = 50 if SMOKE else 400


def _steps_per_s(us: float) -> float:
    return T / (us / 1e6)


def run():
    rows = []
    probs = {}
    best = {k: 0.0 for k in ACCEPT_KINDS}
    for p, d in GRID:
        if d not in probs:
            probs[d] = Quadratic(dim=d, cond=8.0, sigma=1.0, seed=0)
        prob = probs[d]
        x0 = np.ones(d, np.float32)
        for name, mk in KINDS:
            relax = mk()
            _, us_unf = timed(lambda: simulate(
                prob, relax, p, 0.02, T, seed=3, x0=x0, fused=False),
                warmup=1, iters=3, best=True)
            _, us_fus = timed(lambda: simulate(
                prob, relax, p, 0.02, T, seed=3, x0=x0, fused=True),
                warmup=1, iters=3, best=True)
            speed = us_unf / us_fus
            if d >= 256 and name in ACCEPT_KINDS:
                best[name] = max(best[name], speed)
            tag = f"sim_step/{name}_p{p}_d{d}"
            rows.append(row(f"{tag}_unfused", us_unf,
                            f"steps_per_s={_steps_per_s(us_unf):.0f}"))
            rows.append(row(
                f"{tag}_fused", us_fus,
                f"steps_per_s={_steps_per_s(us_fus):.0f};"
                f"speedup_vs_unfused={speed:.1f}x"))

    # batched multi-(p, d) grid: stacked same-shape problem instances +
    # alpha/seed cases in ONE compiled program vs the per-case Python loop
    p, d = GRID[0]
    n_prob, alphas, seeds = (2, [0.02], [0]) if SMOKE else \
        (4, [0.01, 0.02], [0, 1])
    gprobs = [Quadratic(dim=d, cond=8.0, sigma=1.0, seed=s)
              for s in range(n_prob)]
    x0 = np.ones(d, np.float32)
    relax = Relaxation("crash_subst", f=3)
    n_runs = n_prob * len(alphas) * len(seeds)

    def looped():
        return [simulate_sweep(pr, relax, p, a, T, seeds, x0=x0)
                for pr in gprobs for a in alphas]

    _, us_loop = timed(looped, warmup=1, iters=3, best=True)
    _, us_grid = timed(lambda: simulate_grid(
        gprobs, relax, p, alphas, T, seeds=seeds, x0=x0),
        warmup=1, iters=3, best=True)
    rows.append(row(
        f"sim_step/grid_crash_subst_p{p}_d{d}_x{n_runs}", us_grid,
        f"runs_per_s={n_runs / (us_grid / 1e6):.1f};"
        f"speedup_vs_loop={us_loop / us_grid:.1f}x"))
    rows.append(row(
        f"sim_step/gridloop_crash_subst_p{p}_d{d}_x{n_runs}", us_loop,
        f"runs_per_s={n_runs / (us_loop / 1e6):.1f}"))

    if not SMOKE:
        for name in ACCEPT_KINDS:
            rows.append(row(
                f"accept/sim_step_fused_{name}_2x_d256", 0.0,
                f"best_speedup={best[name]:.1f}x;"
                + ("ok" if best[name] >= TARGET else "BELOW_2X")))
    return rows
