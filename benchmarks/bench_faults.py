"""Fault-injection acceptance + overhead: the runtime counterpart of the
paper's crash relaxations.

Emitted rows:

  * ``accept/fault_recovery_parity`` — a `repro.launch.supervisor` run whose
    fault plan SIGKILLs the trainer mid-run must restart from the latest
    valid checkpoint and produce, step for step, the SAME loss trajectory
    as one uninterrupted run of the same plan (the oracle: identical flags
    with ``--fault-attempt 1``, so the attempt-0 kill never fires).
    Everything is deterministic in (seed, step) — data, tau tables, delay
    rings, cross-process param init — so the trajectories must agree to
    float-print precision.
  * ``accept/fault_overhead`` — the fault machinery with an EMPTY plan
    attached (per-step host-side event lookups; the jitted program is
    unchanged) must cost < 2% steps/s against the same loop with no
    injector at all.

The training loops run in subprocesses (XLA_FLAGS device forcing, and the
SIGKILL must kill a child, not the bench harness); children print
``BENCHROW|name|us|derived`` lines the parent converts to rows.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from benchmarks.common import row

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))
STEPS = 10 if SMOKE else 16
KILL_AT = 6 if SMOKE else 9
CKPT_EVERY = 4
OVH_STEPS = 12 if SMOKE else 40

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env(devices: int = 0):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    return env


def _losses_by_step(out: str) -> dict:
    """``step N loss X`` lines; last occurrence per step wins (the restarted
    attempt replays the steps since its checkpoint)."""
    losses = {}
    for line in out.splitlines():
        if line.startswith("step"):
            parts = line.split()
            losses[int(parts[1])] = float(parts[3])
    return losses


def _recovery_rows() -> list:
    import tempfile

    from repro.faults import FaultEvent, FaultPlan

    with tempfile.TemporaryDirectory() as tmp:
        plan = os.path.join(tmp, "plan.json")
        FaultPlan(events=(FaultEvent(step=KILL_AT, kind="kill"),)).save(plan)
        train = ["--arch", "qwen3-1.7b-smoke", "--steps", str(STEPS),
                 "--batch", "8", "--seq", "32", "--lr", "0.02",
                 "--sync", "async", "--devices", "2", "--tau-max", "2",
                 "--async-schedule", "roundrobin", "--log-every", "1",
                 "--ckpt-every", str(CKPT_EVERY)]
        t0 = time.perf_counter()
        sup = subprocess.run(
            [sys.executable, "-m", "repro.launch.supervisor",
             "--max-restarts", "2", "--backoff", "0.1",
             "--fault-plan", plan, "--",
             *train, "--ckpt-dir", os.path.join(tmp, "ckpt")],
            env=_env(), capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(_SRC))
        dt = time.perf_counter() - t0
        if sup.returncode != 0:
            raise RuntimeError(f"supervised run failed:\n{sup.stdout[-2000:]}"
                               f"\n{sup.stderr[-2000:]}")
        oracle = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", *train,
             "--ckpt-dir", os.path.join(tmp, "ckpt_oracle"),
             "--fault-plan", plan, "--fault-attempt", "1"],
            env=_env(), capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(_SRC))
        if oracle.returncode != 0:
            raise RuntimeError(f"oracle run failed:\n{oracle.stdout[-2000:]}"
                               f"\n{oracle.stderr[-2000:]}")
    got, want = _losses_by_step(sup.stdout), _losses_by_step(oracle.stdout)
    killed = "fault: SIGKILL" in sup.stdout
    resumed = "resumed from step" in sup.stdout
    diff = max((abs(got[t] - want[t]) for t in want if t in got),
               default=float("inf"))
    complete = set(got) == set(want) == set(range(STEPS))
    ok = killed and resumed and complete and diff <= 1e-4
    status = "OK" if ok else "FAIL"
    return [row(
        "accept/fault_recovery_parity", dt * 1e6 / STEPS,
        f"SIGKILL@{KILL_AT} restarted={resumed} max|dloss|={diff:.2e} "
        f"<=1e-4 vs uninterrupted oracle over {STEPS} steps: {status}")]


def _overhead_child() -> None:
    import jax
    from jax.sharding import NamedSharding

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMDataset
    from repro.dist import sharding as SH
    from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                         make_async_train_step)
    from repro.faults import FaultPlan, TrainFaultInjector
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as TF
    from repro.models.params import init_params, param_specs
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b").reduced()
    mesh = make_host_mesh()
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params0 = init_params(defs, jax.random.PRNGKey(0))
    opt = momentum(0.02, 0.9)
    data = SyntheticLMDataset(cfg.vocab_size, 32, 8, seed=0)

    def shard_batch(b):
        return {k: jax.device_put(
                    v, NamedSharding(mesh, SH.batch_spec(mesh, v.shape[0])))
                for k, v in b.items()}

    batches = [shard_batch(data.batch(t)) for t in range(OVH_STEPS)]
    acfg = AsyncConfig(tau_max=2, schedule="uniform", horizon=OVH_STEPS,
                       track_gap=False)
    astep = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                          flags))

    def train(injector):
        params, opt_state = params0, opt.init(params0)
        state = init_async_state(acfg, mesh, params0)
        jax.block_until_ready(astep(params, opt_state, state, batches[0]))
        t0 = time.perf_counter()
        for t, b in enumerate(batches):
            params, opt_state, state, m = astep(params, opt_state, state, b)
            if injector is not None:
                # exactly launch.train's per-step host work for a plan with
                # nothing scheduled: event lookups + the kill check
                injector.check_ckpt_io(t + 1)
                injector.maybe_kill(t)
        jax.block_until_ready(params)
        return time.perf_counter() - t0

    # interleave the two variants and keep each one's best time, so a
    # scheduling hiccup cannot fake (or hide) a regression
    base_dt = inj_dt = float("inf")
    for _ in range(3):
        base_dt = min(base_dt, train(None))
        inj_dt = min(inj_dt, train(TrainFaultInjector(FaultPlan())))
    overhead = inj_dt / base_dt - 1.0
    status = "OK" if overhead < 0.02 else "FAIL"
    print(f"BENCHROW|accept/fault_overhead|{inj_dt / OVH_STEPS * 1e6:.1f}|"
          f"empty-plan injector {overhead * 100:+.2f}% steps/s vs no "
          f"injector ({OVH_STEPS / inj_dt:.1f} vs {OVH_STEPS / base_dt:.1f}"
          f" steps/s) <2%: {status}", flush=True)


def _overhead_rows() -> list:
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_faults", "--child"],
        env=_env(devices=2), capture_output=True, text=True, timeout=3600,
        cwd=os.path.dirname(_SRC))
    if r.returncode != 0:
        raise RuntimeError(f"bench_faults child failed:\n{r.stdout[-2000:]}"
                           f"\n{r.stderr[-2000:]}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("BENCHROW|"):
            _, name, us, derived = line.split("|", 3)
            rows.append(row(name, float(us), derived))
    if not rows:
        raise RuntimeError(f"no BENCHROW output:\n{r.stdout[-2000:]}")
    return rows


def run() -> list:
    return _recovery_rows() + _overhead_rows()


if __name__ == "__main__":
    if "--child" in sys.argv:
        _overhead_child()
    else:
        from benchmarks.common import print_rows
        print_rows(run())
