"""Figure 1 (left) / Figure 2: elastic bound vs final accuracy.

The paper's correlation chain, measured in two panels on the non-convex MLP:
  (a) beta -> B_hat: tightening the norm-bounded scheduler's gate reduces
      the measured elastic constant (the knob controls the bound);
  (b) B -> accuracy: the realized consistency bound determines final
      accuracy/loss (swept directly with the Def.-1 oracle so the whole
      Figure-1-left x-axis is covered — the 1-step scheduler alone only
      reaches small B on this testbed, where accuracy is flat, consistent
      with the paper's "full recovery for small beta" finding).

Each panel is ONE ``simulate_grid`` call: beta / B_adv are traced knobs, so
the whole sweep (all knob values x all seeds) shares a single compiled
program instead of the per-value Python loop of sweeps this bench used to
run.  Per-value rows carry the grid call's per-value time share; the
``grid_total`` rows carry the whole-call wall time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate_grid

P, T, ALPHA = 8, 600, 0.08
SEEDS = (4, 5, 6)
BETAS = (0.0, 0.2, 0.5, 0.8, 1.0)
B_ADVS = (0.0, 5.0, 20.0, 60.0)


def _accuracy(mlp, x):
    w1, b1, w2, b2 = mlp._unflatten(jnp.asarray(x))
    h = jnp.tanh(mlp.xs @ w1 + b1)
    pred = jnp.argmax(h @ w2 + b2, axis=-1)
    return float(jnp.mean((pred == mlp.ys).astype(jnp.float32)))


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    rows = []
    # (a) beta controls the measured bound (seed-mean, one compiled program)
    relaxes = [Relaxation("elastic_norm", beta=b) for b in BETAS]
    grid, us = timed(lambda: simulate_grid(
        mlp, relaxes, P, ALPHA, T, seeds=SEEDS, x0=x0), iters=1)
    rows.append(row("fig1_left/grid_betas", us,
                    f"cases={len(BETAS) * len(SEEDS)};seeds={len(SEEDS)}"))
    for ib, beta in enumerate(BETAS):
        batch = grid.select(i_relax=ib)
        acc = float(np.mean([_accuracy(mlp, r.x_final) for r in batch]))
        rows.append(row(
            f"fig1_left/beta_{beta}", us / len(BETAS),
            f"B_hat={np.mean([r.b_hat for r in batch]):.2f};"
            f"loss={np.mean([r.losses[-1] for r in batch]):.4f};"
            f"acc={acc:.3f};seeds={len(SEEDS)}"))
    # (b) the bound controls accuracy (Def.-1 oracle sweep, one program)
    adv = [Relaxation("adversarial", B_adv=b) for b in B_ADVS]
    agrid, us = timed(lambda: simulate_grid(
        mlp, adv, P, ALPHA, T, seeds=(4,), x0=x0), iters=1)
    rows.append(row("fig1_left/grid_bounds", us, f"cases={len(B_ADVS)}"))
    accs = {}
    for ib, b in enumerate(B_ADVS):
        res = agrid[(0, ib, P, 0, 4)]
        acc = _accuracy(mlp, res.x_final)
        accs[b] = acc
        rows.append(row(
            f"fig1_left/bound_B{b:g}", us / len(B_ADVS),
            f"loss={res.losses[-1]:.4f};acc={acc:.3f}"))
    mono = accs[0.0] >= accs[20.0] >= accs[60.0]
    rows.append(row("fig1_left/accuracy_decreases_with_B", 0.0,
                    "ok" if mono else "VIOLATION"))
    return rows
