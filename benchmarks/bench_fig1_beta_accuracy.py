"""Figure 1 (left) / Figure 2: elastic bound vs final accuracy.

The paper's correlation chain, measured in two panels on the non-convex MLP:
  (a) beta -> B_hat: tightening the norm-bounded scheduler's gate reduces
      the measured elastic constant (the knob controls the bound);
  (b) B -> accuracy: the realized consistency bound determines final
      accuracy/loss (swept directly with the Def.-1 oracle so the whole
      Figure-1-left x-axis is covered — the 1-step scheduler alone only
      reaches small B on this testbed, where accuracy is flat, consistent
      with the paper's "full recovery for small beta" finding).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate, simulate_sweep

P, T, ALPHA = 8, 600, 0.08
SEEDS = (4, 5, 6)


def _accuracy(mlp, x):
    w1, b1, w2, b2 = mlp._unflatten(jnp.asarray(x))
    h = jnp.tanh(mlp.xs @ w1 + b1)
    pred = jnp.argmax(h @ w2 + b2, axis=-1)
    return float(jnp.mean((pred == mlp.ys).astype(jnp.float32)))


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    rows = []
    # (a) beta controls the measured bound (seed-mean via the vmapped sweep)
    for beta in (0.0, 0.2, 0.5, 0.8, 1.0):
        batch, us = timed(lambda b=beta: simulate_sweep(
            mlp, Relaxation("elastic_norm", beta=b), P, ALPHA, T, SEEDS,
            x0=x0), iters=1)
        acc = float(np.mean([_accuracy(mlp, r.x_final) for r in batch]))
        rows.append(row(
            f"fig1_left/beta_{beta}", us,
            f"B_hat={np.mean([r.b_hat for r in batch]):.2f};"
            f"loss={np.mean([r.losses[-1] for r in batch]):.4f};"
            f"acc={acc:.3f};seeds={len(SEEDS)}"))
    # (b) the bound controls accuracy (Def.-1 oracle sweep)
    accs = {}
    for b in (0.0, 5.0, 20.0, 60.0):
        res, us = timed(lambda bb=b: simulate(
            mlp, Relaxation("adversarial", B_adv=bb), P, ALPHA, T, seed=4,
            x0=x0), iters=1)
        acc = _accuracy(mlp, res.x_final)
        accs[b] = acc
        rows.append(row(
            f"fig1_left/bound_B{b:g}", us,
            f"loss={res.losses[-1]:.4f};acc={acc:.3f}"))
    mono = accs[0.0] >= accs[20.0] >= accs[60.0]
    rows.append(row("fig1_left/accuracy_decreases_with_B", 0.0,
                    "ok" if mono else "VIOLATION"))
    return rows
