"""Kernel micro-benchmarks (interpret-mode wall time is CPU-bound and NOT a
TPU estimate — the derived field carries the analytic VMEM-traffic model the
TPU roofline uses)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.kernels.onebit_ef import onebit_ef
from repro.kernels.swa_attention import swa_decode_attention
from repro.kernels.topk_ef import topk_ef


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    m, r, k = 64, 4096, 64
    g = jax.random.normal(key, (m, r))
    e = jnp.zeros((m, r))
    out, us = timed(lambda: jax.block_until_ready(
        topk_ef(g, e, k=k, interpret=True)))
    wire = m * k * 8
    rows.append(row("kernels/topk_ef_64x4096_k64", us,
                    f"wire_bytes={wire};dense_bytes={m*r*4};"
                    f"reduction={m*r*4/wire:.1f}x"))

    out, us = timed(lambda: jax.block_until_ready(
        onebit_ef(g, e, interpret=True)))
    wire = m * r // 8 + m * 8
    rows.append(row("kernels/onebit_ef_64x4096", us,
                    f"wire_bytes={wire};dense_bytes={m*r*4};"
                    f"reduction={m*r*4/wire:.1f}x"))

    b, t, kv, gq, d = 1, 4096, 2, 4, 128
    q = jax.random.normal(key, (b, kv, gq, d), jnp.bfloat16)
    kc = jax.random.normal(key, (b, t, kv, d), jnp.bfloat16)
    vc = jax.random.normal(key, (b, t, kv, d), jnp.bfloat16)
    out, us = timed(lambda: jax.block_until_ready(
        swa_decode_attention(q, kc, vc, jnp.int32(t - 1), window=1024,
                             interpret=True)))
    hbm = 2 * t * kv * d * 2          # one cache read
    xla_hbm = hbm + b * kv * gq * t * 4 * 2  # + score row materialization
    rows.append(row("kernels/swa_decode_1x4096_w1024", us,
                    f"kernel_hbm_bytes={hbm};xla_fallback_bytes={xla_hbm};"
                    f"traffic_saving={xla_hbm/hbm:.2f}x"))
    return rows
