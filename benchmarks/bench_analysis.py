"""Static-analysis acceptance rows: the analyzer itself as a bench gate.

Runs the `repro.analysis` CLI (lint + ring model checker + jaxpr audit) in
a subprocess — the audit re-traces every public entry point, which must
not inherit this process's already-initialized jax — and converts its JSON
report into bench rows:

  * ``accept/analysis_clean`` — PASS iff the CLI exits 0, i.e. every
    finding is either fixed or justified in ``analysis/baseline.json``.
    The us column is the end-to-end analyzer wall time.
  * ``analysis/bytes_on_wire_<strategy>`` — the jaxpr-model bytes/step for
    each audited sync strategy (the us column carries the byte count so
    the communication-reduction trajectory is tracked across PRs; the
    compressed strategies must stay strictly below ``sync``).

``BENCH_SIM_SMOKE=1`` passes ``--fast --no-compile``: trimmed ring spaces
and trace-only donation checks, same pass/fail semantics.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks.common import row

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run() -> list:
    with tempfile.TemporaryDirectory() as td:
        report_path = os.path.join(td, "analysis.json")
        cmd = [sys.executable, "-m", "repro.analysis", "--all",
               "--baseline", "analysis/baseline.json",
               "--json", report_path]
        if SMOKE:
            cmd += ["--fast", "--no-compile"]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        t0 = time.perf_counter()
        proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                              text=True, timeout=1800)
        dt_us = (time.perf_counter() - t0) * 1e6
        if not os.path.exists(report_path):
            raise RuntimeError(
                f"analysis CLI produced no report (exit {proc.returncode}):\n"
                f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
        with open(report_path) as fh:
            report = json.load(fh)

    new = report.get("new", [])
    n_total = len(report.get("findings", []))
    clean = proc.returncode == 0 and not new
    verdict = (f"PASS({n_total} findings, all baselined)" if clean
               else f"FAIL({len(new)} new findings)")
    rows = [row("accept/analysis_clean", dt_us, verdict)]
    strat = report.get("info", {}).get("audit", {}) \
                  .get("bytes_on_wire_by_strategy", {})
    for name in sorted(strat):
        rows.append(row(f"analysis/bytes_on_wire_{name}", float(strat[name]),
                        "jaxpr-model bytes/step"))
    if not clean:
        for f in new[:5]:
            print(f"NEW {f.get('rule')} {f.get('where')}: {f.get('detail')}",
                  file=sys.stderr)
        raise RuntimeError(f"analysis found {len(new)} unbaselined findings")
    return rows
