"""Figure 3 (right): variance-bounded elastic scheduler — accuracy per
epoch vs the perfectly-consistent baseline (paper: run without momentum).

Both strategies x all seeds run in ONE ``simulate_grid`` call (the sync and
variance-bounded groups each compile once and vmap over seeds), and the
recovered-accuracy check compares seed-mean accuracies, not single
trajectories."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate_grid

P, T, ALPHA = 8, 800, 0.08
SEEDS = (4, 5, 6, 7)
CASES = [("sync", Relaxation("sync")),
         ("variance_bounded", Relaxation("elastic_variance", drop_prob=0.3))]


def _accuracy(mlp, x):
    w1, b1, w2, b2 = mlp._unflatten(jnp.asarray(x))
    h = jnp.tanh(mlp.xs @ w1 + b1)
    pred = jnp.argmax(h @ w2 + b2, axis=-1)
    return float(jnp.mean((pred == mlp.ys).astype(jnp.float32)))


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    grid, us = timed(lambda: simulate_grid(
        mlp, [r for _, r in CASES], P, ALPHA, T, seeds=SEEDS, x0=x0),
        iters=1)
    rows = [row("fig3_right/grid_total", us,
                f"cases={len(CASES) * len(SEEDS)}")]
    accs = {}
    for ir, (name, _) in enumerate(CASES):
        batch = grid.select(i_relax=ir)
        acc_s = [_accuracy(mlp, res.x_final) for res in batch]
        accs[name] = float(np.mean(acc_s))
        rows.append(row(
            f"fig3_right/{name}", us / len(CASES),
            f"loss={np.mean([r.losses[-1] for r in batch]):.4f};"
            f"acc={accs[name]:.3f}+-{np.std(acc_s):.3f};"
            f"B_hat={np.mean([r.b_hat for r in batch]):.2f};"
            f"seeds={len(SEEDS)}"))
    recovered = accs["variance_bounded"] >= accs["sync"] - 0.05
    rows.append(row("fig3_right/accuracy_recovered", 0.0,
                    "ok" if recovered else "VIOLATION"))
    return rows
