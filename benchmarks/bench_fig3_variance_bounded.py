"""Figure 3 (right): variance-bounded elastic scheduler — accuracy per
epoch vs the perfectly-consistent baseline (paper: run without momentum)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.problems import MLPClassification
from repro.core.sim import Relaxation, simulate

P, T, ALPHA = 8, 800, 0.08


def _accuracy(mlp, x):
    w1, b1, w2, b2 = mlp._unflatten(jnp.asarray(x))
    h = jnp.tanh(mlp.xs @ w1 + b1)
    pred = jnp.argmax(h @ w2 + b2, axis=-1)
    return float(jnp.mean((pred == mlp.ys).astype(jnp.float32)))


def run():
    mlp = MLPClassification(seed=0)
    x0 = np.asarray(mlp.init(seed=1))
    rows = []
    accs = {}
    for name, relax in [("sync", Relaxation("sync")),
                        ("variance_bounded",
                         Relaxation("elastic_variance", drop_prob=0.3))]:
        res, us = timed(lambda r=relax: simulate(mlp, r, P, ALPHA, T, seed=4,
                                                 x0=x0), iters=1)
        acc = _accuracy(mlp, res.x_final)
        accs[name] = acc
        rows.append(row(f"fig3_right/{name}", us,
                        f"loss={res.losses[-1]:.4f};acc={acc:.3f};"
                        f"B_hat={res.b_hat:.2f}"))
    recovered = accs["variance_bounded"] >= accs["sync"] - 0.05
    rows.append(row("fig3_right/accuracy_recovered", 0.0,
                    "ok" if recovered else "VIOLATION"))
    return rows
