"""Theorems 4/5: strongly-convex convergence — E||x_T - x*||^2 under the
prescribed alpha = 2(logT + logp)/(cT) vs the theorem RHS, for the sync
baseline (B=0) and the variance-bounded elastic scheduler (B = 3 sigma)."""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import row, timed
from repro.core import theory
from repro.core.problems import Quadratic
from repro.core.sim import Relaxation, simulate

P, DIM = 8, 32


def run():
    prob = Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)
    x0 = np.ones(DIM, np.float32) * 2.0
    pc = prob.constants(x0)
    rows = []
    for T in (400, 800, 1600):
        alpha = 2 * (math.log(T) + math.log(P)) / (prob.c * T)
        for name, relax, b in [
            ("sync", Relaxation("sync"), 0.0),
            ("elastic_var", Relaxation("elastic_variance", drop_prob=0.3),
             theory.b_elastic_scheduler_variance(prob.sigma2)),
        ]:
            res, us = timed(lambda r=relax, a=alpha, t=T: simulate(
                prob, r, P, a, t, seed=5, x0=x0), iters=1)
            dist2 = float(np.sum(
                (res.x_final - np.asarray(prob.x_star)) ** 2))
            rhs = theory.thm5_rhs(pc, b, T, P)
            rows.append(row(
                f"thm5_strongly_convex/{name}_T{T}", us,
                f"dist2={dist2:.5f};thm5_rhs={rhs:.5f};"
                f"{'ok' if dist2 <= rhs else 'VIOLATION'}"))
    return rows
