"""Shared benchmark utilities. Every bench returns rows
(name, us_per_call, derived) and run.py prints the CSV."""
from __future__ import annotations

import time


def timed(fn, *args, warmup: int = 1, iters: int = 3, best: bool = False):
    """Time fn; returns (out, us_per_call). ``best=True`` reports the
    fastest iteration instead of the mean (robust on noisy machines)."""
    for _ in range(warmup):
        out = fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        times.append(time.perf_counter() - t0)
    dt = min(times) if best else sum(times) / iters
    return out, dt * 1e6  # us


def row(name: str, us: float, derived: str) -> tuple:
    return (name, round(us, 1), derived)


def print_rows(rows):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
