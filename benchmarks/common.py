"""Shared benchmark utilities. Every bench returns rows
(name, us_per_call, derived) and run.py prints the CSV."""
from __future__ import annotations

import time


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        out = fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return out, dt * 1e6  # us


def row(name: str, us: float, derived: str) -> tuple:
    return (name, round(us, 1), derived)


def print_rows(rows):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
