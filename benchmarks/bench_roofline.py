"""Roofline analysis (deliverable g): three terms per (arch x shape) from
the dry-run artifacts, dominant bottleneck, and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPS.

Hardware model (TPU v5e-class): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link — per chip. Reads experiments/dryrun/*.json (single-pod,
exact sync) and writes experiments/roofline.md.  When no artifacts exist
it dry-runs the smoke arch's serving shapes itself (subprocess:
`launch.dryrun` must set XLA_FLAGS before jax initializes, which cannot
happen in this already-initialized harness process).  When the dry-run is
unavailable too (CI fast lanes set ``BENCH_SIM_SMOKE`` to skip the
multi-minute compile), the three terms come from the cluster model's
analytic cost points (`repro.cluster.analytic_record`) — real rows either
way; the ``no_dryrun_artifacts`` placeholder only survives as a last
resort and then carries the dry-run's stderr tail instead of swallowing
it.
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import sys
import warnings

from benchmarks.common import row
from repro.configs import INPUT_SHAPES, get_config

SMOKE = bool(os.environ.get("BENCH_SIM_SMOKE"))

#: (arch, shapes) the self-dry-run and the analytic fallback cover
FALLBACK_ARCH = "qwen3-1.7b-smoke"
FALLBACK_SHAPES = ("prefill_32k", "decode_32k", "train_4k")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def model_flops_global(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "costs" not in rec:
        return None
    c = rec["costs"]
    t_compute = c["flops"] / PEAK_FLOPS
    t_memory = c["bytes"] / HBM_BW
    t_coll = c["collectives"].get("total", 0.0) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global(rec["arch"], rec["shape"]) / CHIPS
    ratio = mf / max(c["flops"], 1e-9)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf, "hlo_flops_per_chip": c["flops"],
        "useful_ratio": ratio,
        "peak_mem_gb": rec.get("memory", {}).get("peak_per_device_gb"),
        "collective_breakdown_gib": {
            k: v / 2**30 for k, v in c["collectives"].items()},
    }


def load_all(sync: str = "exact", suffix: str = "") -> list[dict]:
    """Analyze every matching dry-run artifact.  Corrupt or torn files
    (e.g. a dry-run killed mid-write) are skipped with a warning instead
    of sinking the whole bench — the same sidecar-tolerant posture as
    `checkpoint.ckpt.latest_step`."""
    out = []
    for path in sorted(glob.glob(
            os.path.join(DRYRUN_DIR, f"*__single__{sync}{suffix}.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(f"skipping unreadable dryrun artifact {path}: {e}")
            continue
        a = analyze_record(rec)
        if a:
            out.append(a)
    return out


def write_markdown(rows: list[dict], path: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("| arch | shape | t_compute (ms) | t_memory (ms) | "
                "t_collective (ms) | dominant | useful ratio | mem GB |\n")
        f.write("|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} | "
                f"{r['t_collective_s']*1e3:.1f} | {r['dominant']} | "
                f"{r['useful_ratio']:.2f} | {r['peak_mem_gb']} |\n")


def self_dryrun(arch: str = FALLBACK_ARCH,
                shapes: str = "prefill_32k,decode_32k",
                timeout: float = 1500.0) -> tuple[bool, str]:
    """Produce dry-run artifacts for the smoke arch's serving shapes.

    Returns ``(ok, diagnostic)``: on failure the diagnostic is the
    subprocess stderr tail (or the exception), never a generic shrug —
    the placeholder row used to swallow exactly this.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shapes, "--mesh", "single", "--out", DRYRUN_DIR,
           "--skip-existing"]
    try:
        proc = subprocess.run(cmd, env=env, timeout=timeout,
                              capture_output=True, text=True)
    except (subprocess.TimeoutExpired, OSError) as e:
        return False, f"{type(e).__name__}: {e}"
    if proc.returncode == 0:
        return True, ""
    tail = " | ".join((proc.stderr or "").strip().splitlines()[-3:])
    return False, f"rc={proc.returncode}: {tail or 'no stderr'}"


def analytic_rows() -> list[dict]:
    """The three roofline terms from the cluster model's analytic cost
    points — no compile, no artifacts, same row schema (the ``src=model``
    note in `run` marks their provenance)."""
    from repro.cluster import analytic_record
    out = []
    for shape in FALLBACK_SHAPES:
        a = analyze_record(analytic_record(FALLBACK_ARCH, shape,
                                           chips=CHIPS))
        if a:
            out.append(a)
    return out


def run():
    rows_data = load_all()
    src, diag = "dryrun", ""
    if not rows_data and not SMOKE:
        # the real thing: compile the shapes and read HLO cost analysis
        _, diag = self_dryrun()
        rows_data = load_all()
    if not rows_data:
        # cluster-model fallback: analytic cost points, real rows
        rows_data = analytic_rows()
        src = "model"
    if rows_data:
        write_markdown(rows_data, "experiments/roofline.md")
    rows = []
    for r in rows_data:
        rows.append(row(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"tc={r['t_compute_s']*1e3:.1f}ms;tm={r['t_memory_s']*1e3:.1f}ms;"
            f"tx={r['t_collective_s']*1e3:.1f}ms;dom={r['dominant']};"
            f"useful={r['useful_ratio']:.2f};mem={r['peak_mem_gb']}GB;"
            f"src={src}"))
    if not rows:
        why = (f"self dry-run failed: {diag}" if diag
               else "run python -m repro.launch.dryrun first")
        rows.append(row("roofline/no_dryrun_artifacts", 0.0, why))
    return rows
