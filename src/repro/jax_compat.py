"""Compat shims for jax.sharding / shard_map API drift.

Same pattern as `repro.kernels.pltpu_compat`: each renamed/moved jax API
the production path touches is absorbed in exactly one function here, so
the trainer and the multidevice tests run unchanged across the jax 0.4/0.5+
series:

  * ``jax.sharding.AxisType`` (+ the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exists on newer jax — :func:`make_mesh` passes
    Auto axis types when available and plain meshes otherwise,
  * ``jax.shard_map`` was promoted from ``jax.experimental.shard_map`` with
    ``check_rep`` renamed to ``check_vma`` — :func:`shard_map` routes to
    whichever exists.
"""
from __future__ import annotations

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` when this jax has AxisType, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    return jax.make_mesh(shape, names, **axis_types_kwargs(len(shape)))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map`` over all mesh axes.  ``check`` maps to
    ``check_vma`` (new jax) / ``check_rep`` (old jax)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
