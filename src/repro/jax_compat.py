"""Compat shims for jax.sharding / shard_map API drift.

Same pattern as `repro.kernels.pltpu_compat`: each renamed/moved jax API
the production path touches is absorbed in exactly one function here, so
the trainer and the multidevice tests run unchanged across the jax 0.4/0.5+
series:

  * ``jax.sharding.AxisType`` (+ the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exists on newer jax — :func:`make_mesh` passes
    Auto axis types when available and plain meshes otherwise,
  * ``jax.shard_map`` was promoted from ``jax.experimental.shard_map`` with
    ``check_rep`` renamed to ``check_vma`` — :func:`shard_map` routes to
    whichever exists.
"""
from __future__ import annotations

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` when this jax has AxisType, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    return jax.make_mesh(shape, names, **axis_types_kwargs(len(shape)))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False,
              auto: frozenset = frozenset()):
    """Version-portable ``shard_map``.  ``check`` maps to ``check_vma``
    (new jax) / ``check_rep`` (old jax).

    ``auto`` names mesh axes left to the compiler (GSPMD) instead of being
    manually mapped over — the trainer runs the data-parallel sync collectives
    manually over the ``data``/``pod`` axes while tensor parallelism over
    ``model`` stays automatic. Old jax exposes this as ``auto=``; newer jax
    inverts it into ``axis_names=`` (the manual axes), so both spellings are
    absorbed here.
    """
    import inspect

    auto = frozenset(auto)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        params = inspect.signature(sm).parameters
        kw = {("check_vma" if "check_vma" in params else "check_rep"): check}
        if auto:
            if "auto" in params:
                kw["auto"] = auto
            elif "axis_names" in params:
                kw["axis_names"] = set(mesh.axis_names) - auto
            else:  # pragma: no cover - future drift
                raise TypeError("this jax.shard_map has no auto/axis_names")
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": check}
    if auto:
        kw["auto"] = auto
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
