"""Learning-rate schedules, including the paper's theorem-prescribed rates."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    """Constant schedule.  The array is materialized ONCE at build time and
    closed over — the previous per-call ``jnp.asarray(lr)`` allocated a
    fresh device buffer every eager invocation and re-staged the constant
    on every trace (flagged by ``repro.analysis``'s jaxpr auditor; pinned
    by the retrace-hazard regression test in ``tests/test_analysis.py``)."""
    arr = jnp.asarray(lr, jnp.float32)
    return lambda step: arr


def cosine_decay(base: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(base: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_decay(base, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return fn


def paper_nonconvex_lr(T: int, p: int = 1):
    """Theorem 2 (p=1) / Theorem 3 (parallel steps): alpha = sqrt(p/T)."""
    return constant((p / T) ** 0.5)


def paper_strongly_convex_lr(T: int, c: float, p: int = 1):
    """Theorem 4/5: alpha = 2(log T + log p)/(cT)."""
    import math
    return constant(2 * (math.log(T) + math.log(p)) / (c * T))
