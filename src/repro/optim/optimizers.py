"""Pure-JAX optimizers (no optax in this container).

An :class:`Optimizer` is an (init, update) pair over pytrees, mirroring the
optax GradientTransformation contract so the trainer is optimizer-agnostic.
The paper's experiments use SGD with momentum 0.9 (and *no* momentum for the
variance-bounded scheduler runs) — both are first-class here; Adam is
provided for the LM workloads.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def _zeros_like_tree(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def sgd(lr) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        a = lr_fn(state["count"])
        updates = jax.tree.map(lambda g: -a * g, grads)
        return updates, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"count": jnp.zeros((), jnp.int32), "mu": _zeros_like_tree(params)}

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: beta * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: g + beta * m, mu, grads)
        else:
            upd = mu
        a = lr_fn(state["count"])
        updates = jax.tree.map(lambda u: -a * u, upd)
        return updates, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": _zeros_like_tree(params),
            "v": _zeros_like_tree(params),
        }

    def update(grads, state, params=None):
        c = state["count"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        a = lr_fn(state["count"])

        def u(m, v, p):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd + weight_decay * p
            return -a * upd

        if params is None:
            updates = jax.tree.map(lambda m, v: u(m, v, None), m, v)
        else:
            updates = jax.tree.map(u, m, v, params)
        return updates, {"count": c, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
