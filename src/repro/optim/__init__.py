from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, momentum, adam, apply_updates, global_norm, clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant, cosine_decay, warmup_cosine, paper_nonconvex_lr, paper_strongly_convex_lr,
)
