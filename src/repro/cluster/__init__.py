"""Trace-driven cluster performance model + co-simulation.

`spec`  — `ClusterSpec`/`TraceEvent`: JSON-round-trippable fleet shapes
          with seeded straggler/preemption/congestion traces.
`perf`  — the jitted discrete-event loop: measured ``tau(t, worker)``
          tables (DROPPED where preempted) + learner wall-clock curves,
          and the analytic roofline fallback for `bench_roofline`.
`cosim` — joins the event loop with `core.sim_engine.simulate_grid` to
          rank (strategy, tau_max, compressor) by time-to-loss.
"""
from .cosim import (Candidate, CosimResult, DEFAULT_CANDIDATES,
                    load_wire_bytes, rank_candidates, winners)
from .perf import (ClusterRun, analytic_record, durations_table,
                   simulate_cluster, trace_tables)
from .spec import PRESETS, ClusterSpec, TraceEvent, preset

__all__ = [
    "Candidate", "ClusterRun", "ClusterSpec", "CosimResult",
    "DEFAULT_CANDIDATES", "PRESETS", "TraceEvent", "analytic_record",
    "durations_table", "load_wire_bytes", "preset", "rank_candidates",
    "simulate_cluster", "trace_tables", "winners",
]
