"""Discrete-event cluster performance model.

Closes the loop the paper leaves open: Def. 1 bounds *what* staleness may
do to the iterate, this module prices *where it comes from and what it
costs*.  A `ClusterSpec` (rates, bandwidths, trace events) plus a
per-strategy cost point (flops and bytes-on-wire per step) is advanced by
a jitted `lax.scan` event loop under the bounded-staleness discipline:

  begin(t, w) = max(finish(t-1, w), A(t-1-tau_max))          worker gate
  finish(t,w) = begin(t, w) + d_w(t)                         message done
  A(t)        = max(A(t-1) + apply_s, max_w finish(t-tau_max, w))

The learner gate makes the staleness bound *structural*: step ``t`` cannot
close until every alive worker's step ``t - tau_max`` message has landed,
so the measured ``tau(t, worker)`` table the loop emits always satisfies
``0 <= tau <= tau_max`` — the same invariant `core.delivery`'s rings pin —
with `DROPPED` rows exactly where the trace preempts a worker.  ``A`` is
the cumulative wall-clock curve co-simulation reads time-to-loss off.

With ``tau_max = 0`` the recurrence degenerates to bulk-synchronous SGD
(every step waits for the slowest worker), which is what makes straggler
traces price sync vs async honestly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.core.delivery import DROPPED, validate_tau_table

from .spec import ClusterSpec


def trace_tables(spec: ClusterSpec, t_len: int):
    """Expand the spec's trace events into (rates, bandwidth, alive) tables
    of shape ``(t_len, p)`` — host-side, pre-drawn (oblivious adversary,
    same posture as `sim_types.make_schedule`)."""
    rates = np.tile(spec.rates, (t_len, 1))
    bw = np.tile(spec.bandwidth, (t_len, 1))
    alive = np.ones((t_len, spec.p), bool)
    for ev in spec.events:
        w = ev.worker % spec.p
        s = min(ev.step, t_len)
        end = t_len if ev.duration == 0 else min(s + ev.duration, t_len)
        if ev.kind == "straggle":
            rates[s:end, w] /= ev.factor
        elif ev.kind == "netdeg":
            bw[s:end, w] /= ev.factor
        elif ev.kind == "preempt":
            alive[s:end, w] = False
    return rates, bw, alive


def durations_table(spec: ClusterSpec, t_len: int, flops: float,
                    wire_bytes: float, hbm_bytes: float = 0.0):
    """Per-(step, worker) message durations in seconds: the roofline max of
    compute and HBM terms, plus the wire term.  Returns ``(d, alive)``."""
    rates, bw, alive = trace_tables(spec, t_len)
    t_work = np.maximum(flops / rates, hbm_bytes / spec.hbm[None, :])
    d = t_work + wire_bytes / bw + spec.latency[None, :]
    return d.astype(np.float32), alive


def _build_event_scan(tau_max: int):
    """Jitted event loop for a fixed staleness bound.  Registered in
    `analysis.entrypoints` (group ``cluster``) so the jaxpr auditor checks
    it stays collective-free and retrace-stable."""
    cap = tau_max + 1

    @jax.jit
    def cluster_scan(d, alive, apply_s):
        # d: (T, p) f32 durations; alive: (T, p) bool; apply_s: scalar
        p = d.shape[1]

        def tick(carry, xs):
            fin_prev, ring, a_hist = carry
            d_t, alive_t = xs
            a_prev = a_hist[0]              # A(t-1)
            a_old = a_hist[cap - 1]         # A(t-1-tau_max)
            begin = jnp.maximum(fin_prev, a_old)
            fin = jnp.where(alive_t, begin + d_t, a_prev)
            # dead workers park a zero in the ring: it can never gate
            # (A is nonnegative and nondecreasing), like a missing message
            ring = jnp.concatenate(
                [jnp.where(alive_t, fin, 0.0)[None], ring[:-1]], axis=0)
            a_t = jnp.maximum(a_prev + apply_s, jnp.max(ring[cap - 1]))
            a_hist = jnp.concatenate([a_t[None], a_hist[:-1]], axis=0)
            return (fin, ring, a_hist), (fin, a_t)

        carry0 = (jnp.zeros((p,), jnp.float32),
                  jnp.zeros((cap, p), jnp.float32),
                  jnp.zeros((cap,), jnp.float32))
        _, (fins, closes) = jax.lax.scan(tick, carry0, (d, alive))
        return fins, closes

    return cluster_scan


@dataclass(frozen=True)
class ClusterRun:
    """One event-loop rollout: measured staleness + wall-clock."""
    spec: ClusterSpec
    tau_max: int
    taus: np.ndarray       # (T, p) int32, DROPPED where preempted
    closes: np.ndarray     # (T,) cumulative learner wall-clock A(t)
    finishes: np.ndarray   # (T, p) message finish times
    durations: np.ndarray  # (T, p) message durations

    @property
    def total_s(self) -> float:
        return float(self.closes[-1])

    def time_at(self, step: int) -> float:
        """Wall-clock seconds when learner step ``step`` closes."""
        return float(self.closes[min(max(step, 0), len(self.closes) - 1)])

    def tau_histogram(self) -> dict:
        vals, counts = np.unique(self.taus, return_counts=True)
        return {int(v): int(c) for v, c in zip(vals, counts)}


def simulate_cluster(spec: ClusterSpec, t_len: int, tau_max: int,
                     flops_per_step: float, wire_bytes: float,
                     hbm_bytes: float = 0.0) -> ClusterRun:
    """Advance the cluster ``t_len`` steps and extract the measured tau
    table.  The rollout is extended by ``tau_max`` extra steps so every
    message produced inside the horizon has its delivery window closed."""
    t_ext = t_len + tau_max
    d, alive = durations_table(spec, t_ext, flops_per_step, wire_bytes,
                               hbm_bytes)
    fins, closes = _build_event_scan(tau_max)(
        jnp.asarray(d), jnp.asarray(alive), jnp.float32(spec.apply_s))
    fins = np.asarray(fins, np.float64)
    closes = np.asarray(closes, np.float64)
    if tau_max == 0:
        taus = np.zeros((t_len, spec.p), np.int32)
    else:
        # tau(s, w) = #{k in [0, tau_max) : A(s+k) < finish(s, w)}; the
        # learner gate guarantees A(s+tau_max) >= finish(s, w), so the
        # count never exceeds tau_max.
        win = np.lib.stride_tricks.sliding_window_view(
            closes, tau_max)[:t_len]                       # (T, tau_max)
        taus = (win[:, :, None] < fins[:t_len, None, :]).sum(axis=1)
    taus = np.where(alive[:t_len], taus, DROPPED).astype(np.int32)
    validate_tau_table(taus, tau_max)
    return ClusterRun(spec=spec, tau_max=tau_max, taus=taus,
                      closes=closes[:t_len], finishes=fins[:t_len],
                      durations=np.asarray(d[:t_len], np.float64))


# -- analytic roofline terms (bench_roofline fallback) ---------------------

def analytic_record(arch: str, shape_name: str, *, chips: int = 256) -> dict:
    """First-order cost point for (arch, shape), shaped exactly like a
    `launch.dryrun` artifact so `bench_roofline.analyze_record` consumes it
    unchanged.  Used when no dryrun artifacts exist (e.g. CI): flops from
    the parameter-count model, HBM bytes from weight+activation traffic,
    collective bytes from a ring all-reduce of bf16 gradients."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    tokens = shape.global_batch * (
        1 if shape.kind == "decode" else shape.seq_len)
    flops = (6.0 if shape.kind == "train" else 2.0) * n * tokens
    # weights are streamed once per pass (fwd/bwd/opt for train) for
    # batched passes, but re-read per token when decoding
    passes = 3.0 if shape.kind == "train" else 1.0
    weight_bytes = 2.0 * n * passes * (tokens if shape.kind == "decode"
                                       else 1.0)
    act_bytes = 12.0 * tokens * cfg.d_model * cfg.n_layers
    kv_bytes = (4.0 * shape.global_batch * shape.seq_len * cfg.d_model
                if shape.kind == "decode" else 0.0)
    coll = 4.0 * n if shape.kind == "train" else 0.0
    mem_gb = (2.0 * cfg.param_count() + kv_bytes) / chips / 2**30
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "single", "source": "cluster-model",
        "costs": {
            "flops": flops / chips,
            "bytes": (weight_bytes + act_bytes + kv_bytes) / chips,
            "collectives": {"all-reduce": coll / chips,
                            "total": coll / chips},
        },
        "memory": {"peak_per_device_gb": round(mem_gb, 4)},
    }
