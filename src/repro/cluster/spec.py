"""`ClusterSpec`: a seeded, replayable description of a worker fleet.

The paper abstracts *where* staleness comes from (Def. 1 only bounds the
perturbation); Keuper & Pfreundt's ASGD analysis shows the wall-clock win
of relaxing consistency is a function of the cluster's compute/communication
rate ratio.  A `ClusterSpec` pins that ratio down: per-worker sustained
compute rates, HBM and link bandwidths, link latencies, a learner apply
cost, and a seeded trace of straggler/preemption events.  Like
`faults.FaultPlan` it is JSON round-trippable, so the same cluster shape
can be replayed against the event loop (`cluster.perf`), the co-simulation
driver (`cluster.cosim`) and a future real deployment.

Trace event kinds:

  ==============  ====================================================
  ``straggle``    worker ``worker``'s compute rate is divided by
                  ``factor`` from ``step`` for ``duration`` steps
                  (0 = until the end of the run)
  ``preempt``     worker ``worker`` is evicted from ``step`` for
                  ``duration`` steps; its in-flight gradient is lost
                  (DROPPED rows in the emitted tau table)
  ``netdeg``      worker ``worker``'s link bandwidth is divided by
                  ``factor`` for the window (congestion / flaky NIC)
  ==============  ====================================================
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass

import numpy as np

TRACE_KINDS = ("straggle", "preempt", "netdeg")


@dataclass(frozen=True)
class TraceEvent:
    step: int                 # cluster step the event fires at
    kind: str                 # one of TRACE_KINDS
    worker: int               # which worker (modulo p)
    duration: int = 1         # steps it lasts (0 = until end of run)
    factor: float = 4.0       # straggle/netdeg slowdown divisor

    def __post_init__(self):
        if self.kind not in TRACE_KINDS:
            raise ValueError(
                f"unknown trace kind {self.kind!r}; one of {TRACE_KINDS}")
        if self.step < 0:
            raise ValueError(f"event step must be >= 0, got {self.step}")
        if self.duration < 0:
            raise ValueError(f"duration must be >= 0, got {self.duration}")
        if self.factor <= 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")


@dataclass(frozen=True)
class ClusterSpec:
    """A fleet of ``p`` workers feeding one learner.

    Rates are *per worker*; scalars broadcast.  ``flops_per_s`` is the
    sustained model-flops rate, ``hbm_bytes_per_s`` bounds the memory
    roofline term, ``link_bytes_per_s``/``link_latency_s`` price the
    gradient wire, ``apply_s`` is the learner's fixed per-step apply cost.
    """
    name: str = "custom"
    p: int = 4
    flops_per_s: tuple = (197e12,)
    hbm_bytes_per_s: tuple = (819e9,)
    link_bytes_per_s: tuple = (50e9,)
    link_latency_s: tuple = (1e-5,)
    apply_s: float = 1e-4
    events: tuple = ()
    seed: int = 0

    def __post_init__(self):
        if self.p < 1:
            raise ValueError(f"p must be >= 1, got {self.p}")
        for f in ("flops_per_s", "hbm_bytes_per_s", "link_bytes_per_s",
                  "link_latency_s"):
            v = getattr(self, f)
            if np.isscalar(v):
                v = (float(v),)
            v = tuple(float(x) for x in v)
            if len(v) not in (1, self.p):
                raise ValueError(
                    f"{f} must have 1 or p={self.p} entries, got {len(v)}")
            object.__setattr__(self, f, v)
        object.__setattr__(self, "events", tuple(
            e if isinstance(e, TraceEvent) else TraceEvent(**e)
            for e in self.events))

    # -- per-worker vectors ------------------------------------------------
    def _vec(self, field: str) -> np.ndarray:
        v = np.asarray(getattr(self, field), np.float64)
        return np.broadcast_to(v, (self.p,)).copy()

    @property
    def rates(self) -> np.ndarray:
        return self._vec("flops_per_s")

    @property
    def hbm(self) -> np.ndarray:
        return self._vec("hbm_bytes_per_s")

    @property
    def bandwidth(self) -> np.ndarray:
        return self._vec("link_bytes_per_s")

    @property
    def latency(self) -> np.ndarray:
        return self._vec("link_latency_s")

    # -- (de)serialization (replayability, FaultPlan idiom) ----------------
    def to_json(self) -> str:
        d = asdict(self)
        d["events"] = [asdict(e) for e in self.events]
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "ClusterSpec":
        obj = json.loads(text)
        obj["events"] = tuple(TraceEvent(**e) for e in obj.get("events", ()))
        for f in ("flops_per_s", "hbm_bytes_per_s", "link_bytes_per_s",
                  "link_latency_s"):
            if f in obj:
                obj[f] = tuple(obj[f])
        return cls(**obj)

    @classmethod
    def load(cls, path_or_json: str) -> "ClusterSpec":
        """Accepts a file path or inline JSON (starts with ``{``)."""
        text = path_or_json
        if not path_or_json.lstrip().startswith("{"):
            with open(path_or_json) as f:
                text = f.read()
        return cls.from_json(text)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    # -- generation --------------------------------------------------------
    @classmethod
    def random(cls, seed: int, p: int, steps: int, *,
               n_events: int = 4, kinds=TRACE_KINDS,
               base: "ClusterSpec | None" = None) -> "ClusterSpec":
        """Seeded random trace over a (possibly preset) base fleet.  The
        draw is a pure function of the arguments, so the same seed replays
        the same cluster anywhere."""
        rng = np.random.default_rng(seed)
        base = base or cls(name=f"random{seed}", p=p)
        events = []
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            events.append(TraceEvent(
                step=int(rng.integers(0, max(steps, 1))), kind=kind,
                worker=int(rng.integers(0, max(p, 1))),
                duration=int(rng.integers(1, max(steps // 4, 2))),
                factor=float(rng.uniform(2.0, 16.0))))
        return cls(**{**asdict(base), "name": f"random{seed}", "p": p,
                      "seed": seed,
                      "events": tuple(sorted(events, key=lambda e: e.step))})


# -- named presets (the shapes the co-sim bench sweeps) --------------------

def preset(name: str, p: int = 4, steps: int = 400) -> ClusterSpec:
    """Named cluster shapes.

    ``uniform``         well-provisioned homogeneous pod (fat links, no
                        trace events) — steps and seconds rank the same
    ``straggler_heavy`` commodity fleet: one worker's link is permanently
                        degraded 8x and compute-straggle bursts rotate
                        through the fleet — the shape where a relaxed
                        strategy wins wall-clock while losing the steps
                        race (a *permanent* compute straggler would bound
                        every strategy equally through the delivery gate;
                        jitter + congested wire is what relaxation buys)
    ``preemptible``     spot-instance flavor: periodic preemption windows
                        (DROPPED tau rows) plus mild transient straggles
    """
    base = dict(p=p, flops_per_s=(2e9,), hbm_bytes_per_s=(8e9,),
                link_bytes_per_s=(1e8,), link_latency_s=(1e-3,),
                apply_s=2e-3)
    if name == "uniform":
        return ClusterSpec(name=name, **{**base,
                                         "link_bytes_per_s": (2e9,)})
    if name == "straggler_heavy":
        events = [TraceEvent(step=0, kind="netdeg", worker=p - 1,
                             duration=0, factor=16.0)]
        stride = max(steps // 50, 6)
        for k in range(steps // stride):
            events.append(TraceEvent(
                step=k * stride + 1, kind="straggle", worker=k % p,
                duration=2, factor=6.0))
        return ClusterSpec(
            name=name,
            events=tuple(sorted(events, key=lambda e: e.step)), **base)
    if name == "preemptible":
        events = []
        stride = max(steps // 4, 8)
        for k in range(1, 4):
            events.append(TraceEvent(
                step=k * stride, kind="preempt",
                worker=k % p, duration=max(stride // 3, 2)))
        events.append(TraceEvent(step=stride // 2, kind="straggle",
                                 worker=0, duration=stride, factor=3.0))
        return ClusterSpec(name=name, events=tuple(events), **base)
    raise ValueError(f"unknown cluster preset {name!r}; "
                     f"one of uniform/straggler_heavy/preemptible")


PRESETS = ("uniform", "straggler_heavy", "preemptible")
