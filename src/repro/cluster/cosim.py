"""Co-simulation: convergence x wall-clock, ranked by time-to-loss.

The convergence simulator (`core.sim_engine`) answers "what does staleness
do to the loss" in *steps*; the cluster model (`cluster.perf`) answers
"what does a step cost on *this* cluster" in *seconds*.  This driver joins
them: for each candidate (strategy, tau_max, compressor) it

  1. rolls the cluster event loop under the candidate's staleness bound
     and bytes-on-wire (from the golden collective inventory — the wire
     each strategy was *audited* to use, not a guess),
  2. feeds the measured ``tau(t, worker)`` trace into `simulate_grid`
     via its ``schedule_fn`` hook (so the convergence run experiences the
     cluster's actual staleness, not an abstract uniform draw), and
  3. reads time-to-loss off the learner's wall-clock curve at the step
     where the loss first crosses the target.

Steps-to-loss and time-to-loss rank candidates differently as soon as the
cluster is non-uniform: a straggler/congestion-heavy trace makes the
dense synchronous wire expensive enough that a relaxed strategy (error
feedback compression, bounded staleness) wins wall-clock while *losing*
the steps race — the paper's Def. 1 guarantees it still converges, and
Keuper & Pfreundt's rate-ratio argument says when it pays.

Modeling honesty note: a *permanently* slow worker bounds the learner's
steady-state rate no matter how large ``tau_max`` is — the delivery gate
still waits for its step ``t - tau_max`` message.  Bounded staleness buys
jitter absorption (transient bursts shorter than the tau window) and the
compressed wire buys immunity to link degradation; the presets in
`cluster.spec` are shaped to exercise exactly those two effects.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from repro.core import compression as C
from repro.core.delivery import DROPPED, taus_to_message_delays
from repro.core.problems import Quadratic
from repro.core.sim_engine import simulate_grid
from repro.core.sim_types import Relaxation, Schedule

from .perf import ClusterRun, simulate_cluster
from .spec import ClusterSpec

#: where the per-strategy audited bytes-on-wire live
_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
INVENTORY_PATH = os.path.join(_ROOT, "tests", "golden",
                              "collective_inventory.json")


@dataclass(frozen=True)
class Candidate:
    """One point in the (strategy, tau_max, compressor) design space.

    ``strategy`` keys the golden collective inventory (bytes-on-wire);
    ``sim_kind``/``tau_max``/``compressor`` configure the convergence run.
    For compressed+stale candidates the convergence model uses the async
    kind (staleness dominates at these scales; the compression error is
    second-order and its wire saving is what the cluster model prices).
    """
    name: str
    strategy: str
    sim_kind: str = "sync"        # sync | async | ef_comp
    tau_max: int = 0              # cluster staleness bound (0 = BSP)
    compressor: str = ""          # "" | topk | onebit

    def relaxation(self) -> Relaxation:
        if self.sim_kind == "sync":
            return Relaxation(kind="sync")
        if self.sim_kind == "ef_comp":
            # same ratio as the audited elastic/topk_ef entry, so the
            # wire bytes priced by the cluster model and the compression
            # error seen by the convergence run describe one strategy
            comp = (C.onebit_compressor() if self.compressor == "onebit"
                    else C.topk_compressor(1 / 8))
            return Relaxation(kind="ef_comp", compressor=comp)
        if self.sim_kind == "async":
            # engine requires per-message delay < relax.tau_max, and the
            # measured table satisfies tau <= cluster tau_max
            return Relaxation(kind="async", tau_max=self.tau_max + 1)
        raise ValueError(f"unknown sim kind {self.sim_kind!r}")


DEFAULT_CANDIDATES = (
    Candidate("sync", "sync", "sync", 0),
    Candidate("topk_ef", "topk_ef", "ef_comp", 0, "topk"),
    Candidate("onebit_ef", "onebit_ef", "ef_comp", 0, "onebit"),
    Candidate("async_tau4", "async_tau4", "async", 4),
    Candidate("async_tau4_topk_ef", "async_tau4_topk_ef", "async", 4,
              "topk"),
)


def load_wire_bytes(path: str = INVENTORY_PATH) -> dict:
    """strategy -> audited bytes-on-wire per step, from the golden
    inventory the jaxpr auditor regenerates (`analysis.audit`)."""
    with open(path) as f:
        inv = json.load(f)
    return {k: float(v["wire_bytes"]) for k, v in inv["strategies"].items()}


@dataclass(frozen=True)
class CosimResult:
    """One (cluster, candidate) cell of the co-simulation."""
    cluster: str
    candidate: str
    steps_to_loss: float          # inf if the target was never reached
    time_to_loss: float           # seconds on this cluster's clock
    step_s: float                 # mean learner step duration
    wire_bytes: float
    tau_histogram: dict
    dropped: int                  # preempted (DROPPED) messages


def _first_crossing(losses: np.ndarray, record_every: int,
                    target: float) -> float:
    hits = np.flatnonzero(np.asarray(losses) <= target)
    return float(hits[0] * record_every) if hits.size else float("inf")


def rank_candidates(spec: ClusterSpec, candidates=DEFAULT_CANDIDATES, *,
                    t_len: int = 600, flops_per_step: float = 4e8,
                    problem=None, alpha: float = 0.05,
                    target_frac: float = 0.01, seeds=(0,),
                    record_every: int = 2, wire_table: dict | None = None):
    """Run the full co-simulation on one cluster shape.

    Returns ``(results, cluster_runs)``: a list of :class:`CosimResult`
    (one per candidate) and the per-candidate :class:`ClusterRun` (the
    measured tau tables, for downstream validation).  The loss target is
    ``target_frac`` of the initial loss, shared by all candidates.
    """
    wire = wire_table or load_wire_bytes()
    problem = problem or Quadratic(dim=32, cond=8.0, sigma=0.4, seed=0)
    candidates = tuple(candidates)
    x0 = np.zeros(problem.dim, np.float32)
    target = target_frac * float(problem.loss(x0))

    runs: dict[str, ClusterRun] = {}
    for cand in candidates:
        runs[cand.name] = simulate_cluster(
            spec, t_len, cand.tau_max, flops_per_step,
            wire[cand.strategy])

    relaxations = [cand.relaxation() for cand in candidates]

    def measured_schedule(ir: int, p: int, seed: int):
        cand = candidates[ir]
        if cand.sim_kind != "async":
            return None               # no scheduling randomness to replace
        delays = taus_to_message_delays(runs[cand.name].taus)
        return Schedule(per_step={"delays": delays}, per_run={})

    grid = simulate_grid([problem], relaxations, [spec.p], [alpha], t_len,
                         seeds=tuple(seeds), x0=x0,
                         record_every=record_every,
                         schedule_fn=measured_schedule)

    results = []
    for ir, cand in enumerate(candidates):
        steps = np.mean([
            _first_crossing(
                grid.results[(0, ir, spec.p, 0, s)].losses,
                record_every, target)
            for s in seeds])
        run = runs[cand.name]
        time_s = run.time_at(int(steps)) if np.isfinite(steps) \
            else float("inf")
        results.append(CosimResult(
            cluster=spec.name, candidate=cand.name,
            steps_to_loss=float(steps), time_to_loss=time_s,
            step_s=float(np.diff(run.closes).mean()) if t_len > 1
            else run.total_s,
            wire_bytes=wire[cand.strategy],
            tau_histogram=run.tau_histogram(),
            dropped=int(np.count_nonzero(run.taus == DROPPED))))
    return results, runs


def winners(results) -> dict:
    """The argmin candidate under each metric (ties -> first listed)."""
    finite = [r for r in results if np.isfinite(r.steps_to_loss)]
    if not finite:
        return {"steps": None, "time": None}
    return {
        "steps": min(finite, key=lambda r: r.steps_to_loss).candidate,
        "time": min(finite, key=lambda r: r.time_to_loss).candidate,
    }
