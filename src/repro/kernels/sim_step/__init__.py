from repro.kernels.sim_step.ops import (FUSED_KINDS, delivery_tensors,
                                        fused_delivery_step, fused_sync_step,
                                        supports_fused)

__all__ = ["FUSED_KINDS", "delivery_tensors", "fused_delivery_step",
           "fused_sync_step", "supports_fused"]
