"""Fused simulator-step Pallas TPU kernels.

One kernel launch replaces the whole per-step pipeline of the ``lax.scan``
simulator engine for the `Quadratic` testbed:

  delivery            quadratic gradient        apply
  U (m, p)   x   G = (V - x*) @ A + noise   ->  x' = x - P[0]
                                                 V' = V - P[1:1+p] - defer
                 P = U @ G  (one stacked MXU     defer' = P[1+p:1+2p]
                 matmul for the x-row, the
                 v-rows and the defer rows)

The delivery tensor ``U`` is the relaxation: who receives whose gradient
this step, with the ``alpha/p`` step scale already folded in (see
`ops.delivery_tensors`).  Rows of ``U`` belonging to dead/deferred workers
are zero, so masking needs no extra ``where`` pass.  ``sync`` degenerates
further: every view equals ``x`` exactly, so the kernel collapses to one
(1, d) @ (d, d) matvec plus the pre-summed noise row — a p-fold FLOP cut on
the dense-matvec floor that dominates d >= 256.

Tiling: the grid walks d in ``dn``-wide column blocks (128-lane multiples on
TPU).  Per block the kernel reads the full (p, d) view stack and the
(d, dn) column panel of ``A`` — the (p, d) @ (d, dn) gradient panel and the
(m, p) @ (p, dn) delivery panel both land on the MXU; everything else is
VPU element-wise.  ``interpret=True`` is the CPU path used by the parity
suite (off-TPU perf dispatch uses the fused jnp oracle in `ref.py`, which
is the same math without the interpreter overhead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_panels(v_ref, xstar_ref, a_ref, x_ref, n_ref, u_ref, block_d):
    """Shared MXU body: gradient panel G, delivery panel P = U @ G, and the
    column block of V this grid step updates."""
    j = pl.program_id(0)
    vc = v_ref[...] - xstar_ref[...]                     # (p, d)
    g = jnp.dot(vc, a_ref[...],
                preferred_element_type=jnp.float32) + n_ref[...]
    p_rows = jnp.dot(u_ref[...], g,
                     preferred_element_type=jnp.float32)  # (m, dn)
    v_blk = v_ref[:, pl.dslice(j * block_d, block_d)]
    return p_rows, v_blk


def _delivery_kernel(v_ref, xstar_ref, a_ref, x_ref, n_ref, u_ref,
                     x_out_ref, v_out_ref, *, block_d: int):
    p_rows, v_blk = _fused_panels(v_ref, xstar_ref, a_ref, x_ref, n_ref,
                                  u_ref, block_d)
    n_work = n_ref.shape[0]
    x_out_ref[...] = x_ref[...] - p_rows[0:1, :]
    v_out_ref[...] = v_blk - p_rows[1:1 + n_work, :]


def _delivery_defer_kernel(v_ref, xstar_ref, a_ref, x_ref, n_ref, u_ref,
                           defer_ref, x_out_ref, v_out_ref, defer_out_ref,
                           *, block_d: int):
    p_rows, v_blk = _fused_panels(v_ref, xstar_ref, a_ref, x_ref, n_ref,
                                  u_ref, block_d)
    n_work = n_ref.shape[0]
    x_out_ref[...] = x_ref[...] - p_rows[0:1, :]
    v_out_ref[...] = v_blk - p_rows[1:1 + n_work, :] - defer_ref[...]
    defer_out_ref[...] = p_rows[1 + n_work:1 + 2 * n_work, :]


@functools.partial(jax.jit,
                   static_argnames=("block_d", "has_defer", "interpret"))
def delivery_step(v, x, a, x_star, noise, u, defer=None, *,
                  block_d: int = 256, has_defer: bool = False,
                  interpret: bool = False):
    """One fused simulator step for the delivery-matrix relaxation kinds.

    v (p, d) views; x (1, d); a (d, d); x_star (1, d); noise (p, d) this
    step's pre-drawn gradient noise; u (m, p) scaled delivery tensor with
    m = 1 + p rows (+ p defer rows when ``has_defer``); defer (p, d).
    Returns (x', v'[, defer']).
    """
    p, d = v.shape
    m = u.shape[0]
    assert m == (1 + 2 * p if has_defer else 1 + p), (m, p, has_defer)
    dn = block_d if d % block_d == 0 else d
    grid = (d // dn,)
    blk = lambda rows: pl.BlockSpec((rows, dn), lambda j: (0, j))
    full = lambda rows, cols: pl.BlockSpec((rows, cols), lambda j: (0, 0))
    in_specs = [full(p, d), full(1, d), pl.BlockSpec((d, dn), lambda j: (0, j)),
                blk(1), blk(p), full(m, p)]
    out_specs = [blk(1), blk(p)]
    out_shape = [jax.ShapeDtypeStruct((1, d), jnp.float32),
                 jax.ShapeDtypeStruct((p, d), jnp.float32)]
    operands = [v, x_star, a, x, noise, u]
    if has_defer:
        in_specs.append(blk(p))
        out_specs.append(blk(p))
        out_shape.append(jax.ShapeDtypeStruct((p, d), jnp.float32))
        operands.append(defer)
    kern = functools.partial(
        _delivery_defer_kernel if has_defer else _delivery_kernel,
        block_d=dn)
    out = pl.pallas_call(kern, grid=grid, in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*operands)
    return tuple(out)


def _sync_kernel(x_ref, xstar_ref, a_ref, nsum_ref, c_ref, x_out_ref, *,
                 block_d: int):
    j = pl.program_id(0)
    base = jnp.dot(x_ref[...] - xstar_ref[...], a_ref[...],
                   preferred_element_type=jnp.float32)   # (1, dn)
    x_blk = x_ref[:, pl.dslice(j * block_d, block_d)]
    x_out_ref[...] = x_blk - c_ref[0, 0] * base - nsum_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sync_step(x, a, x_star, nsum, c, *, block_d: int = 256,
              interpret: bool = False):
    """Fused ``sync`` step: all p views equal x exactly, so the gradient
    collapses to one matvec.  x, x_star (1, d); a (d, d); nsum (1, d) the
    pre-scaled worker-summed noise ``(alpha/p) * sum_i noise_i``; c (1, 1)
    the collapsed gradient weight ``alpha`` (= p * alpha/p).  Returns x'.
    """
    _, d = x.shape
    dn = block_d if d % block_d == 0 else d
    blk = pl.BlockSpec((1, dn), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(_sync_kernel, block_d=dn),
        grid=(d // dn,),
        in_specs=[pl.BlockSpec((1, d), lambda j: (0, 0)),
                  pl.BlockSpec((1, d), lambda j: (0, 0)),
                  pl.BlockSpec((d, dn), lambda j: (0, j)), blk,
                  pl.BlockSpec((1, 1), lambda j: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(x, x_star, a, nsum, c)
