"""Pure-jnp oracle for the fused sim_step kernels.

Same math as `kernel.py` without the Pallas interpreter: the row-major
gradient matmul ``(V - x*) @ A + noise``, the stacked delivery matmul
``U @ G`` and the apply, in one traceable function.  Off-TPU this IS the
fast path the simulator engine dispatches to (XLA fuses it well); the
parity suite checks the Pallas kernel (interpret mode) against it
element-for-element.
"""
from __future__ import annotations

import jax.numpy as jnp


def delivery_step_ref(v, x, a, x_star, noise, u, defer=None):
    """v (p, d); x (1, d); a (d, d); x_star (1, d); noise (p, d);
    u (m, p) scale-folded delivery tensor; defer (p, d) or None.
    Returns (x', v') or (x', v', defer')."""
    p = v.shape[0]
    g = (v - x_star) @ a + noise
    p_rows = u @ g
    x_new = x - p_rows[0:1]
    v_new = v - p_rows[1:1 + p]
    if defer is None:
        return x_new, v_new
    return x_new, v_new - defer, p_rows[1 + p:1 + 2 * p]


def sync_step_ref(x, a, x_star, nsum, c):
    """x, x_star, nsum (1, d); a (d, d); c scalar (or (1, 1)).  The p views
    equal x exactly under sync, so one matvec carries the whole step."""
    return x - jnp.reshape(c, ()) * ((x - x_star) @ a) - nsum
