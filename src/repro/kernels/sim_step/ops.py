"""Dispatch + delivery-tensor precompute for the fused simulator step.

The fused fast path rests on two observations about the scan engine's
per-step pipeline on the `Quadratic` testbed:

  1. *Delivery is schedule-determined.*  For the fused relaxation kinds the
     (p, p) "who receives whose gradient" matrices depend only on the
     pre-drawn oblivious-adversary schedule — crash times and hear draws
     for ``crash``/``crash_subst``, drop draws for ``elastic_variance`` —
     never on the iterates.  So the whole run's delivery tensors are built
     in ONE vectorized pass over T before the scan
     (:func:`delivery_tensors`), and the scan step degenerates to the fused
     kernel call: the ~10 small mask/select ops per step that dominate at
     d ~ 256 disappear from the loop body.

  2. *Everything applied is linear in the gradient panel.*  The x-row, the
     p view-rows and (for the 1-step elastic scheduler) the p defer-rows
     are all rows of ``U @ G`` for one stacked (1+p(+p), p) matrix — one
     MXU matmul instead of three.

``impl`` dispatch: ``"kernel"`` is the Pallas TPU kernel (`kernel.py`,
interpret mode off-TPU — used by the parity suite), ``"ref"`` the fused
jnp oracle (`ref.py`), ``"auto"`` picks the kernel on TPU and the oracle
elsewhere (same math; the oracle avoids pure interpreter overhead on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sim_step import kernel as K
from repro.kernels.sim_step import ref as R

#: Relaxation kinds with a fused step.  ``sync`` collapses to one matvec
#: (all views equal x exactly); the others are delivery-tensor kinds.
FUSED_KINDS = ("sync", "crash", "crash_subst", "elastic_variance")


def supports_fused(problem, relax) -> bool:
    """Fused path needs a quadratic-structured problem (dense ``A`` /
    ``x_star`` sim data + presampleable noise) and a fused kind."""
    if relax.kind not in FUSED_KINDS:
        return False
    if not hasattr(problem, "sim_data") or \
            not hasattr(problem, "presample_from_data"):
        return False
    data = problem.sim_data()
    return "A" in data and "x_star" in data


def _resolve_impl(impl: str):
    """-> (use_kernel, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        return on_tpu, False
    if impl == "kernel":
        return True, not on_tpu
    if impl == "ref":
        return False, False
    raise ValueError(impl)


def delivery_tensors(kind: str, p: int, T: int, per_step: dict,
                     per_run: dict, knobs: dict):
    """Whole-run delivery-tensor precompute.  The authoritative
    implementation lives in `repro.core.delivery` (shared with the
    real-model async engine); this re-export keeps the fused-step API in
    one namespace.  Imported at call time: ``repro.core``'s package init
    pulls in `sim_engine`, which imports this package — a module-level
    import here would cycle."""
    from repro.core.delivery import delivery_tensors as _delivery_tensors
    return _delivery_tensors(kind, p, T, per_step, per_run, knobs)


def fused_delivery_step(v, x, a, x_star, noise, u, defer=None, *,
                        impl: str = "auto", block_d: int = 256):
    """One fused step.  v (p, d); x (d,); u (m, p) with the step scale
    already folded in; defer (p, d) or None.  Returns (x', v'[, defer'])
    with x' (d,)."""
    use_kernel, interpret = _resolve_impl(impl)
    x2, xs2 = x[None, :], x_star[None, :]
    if use_kernel:
        out = K.delivery_step(v, x2, a, xs2, noise, u, defer,
                              block_d=block_d, has_defer=defer is not None,
                              interpret=interpret)
    else:
        out = R.delivery_step_ref(v, x2, a, xs2, noise, u, defer)
    return (out[0][0], *out[1:])


def fused_sync_step(x, a, x_star, nsum, c, *, impl: str = "auto",
                    block_d: int = 256):
    """One fused sync step.  x, x_star, nsum (d,); c scalar.  nsum must be
    pre-scaled by alpha/p; c is the collapsed gradient weight alpha."""
    use_kernel, interpret = _resolve_impl(impl)
    if use_kernel:
        out = K.sync_step(x[None, :], a, x_star[None, :], nsum[None, :],
                          jnp.reshape(c, (1, 1)), block_d=block_d,
                          interpret=interpret)
    else:
        out = R.sync_step_ref(x[None, :], a, x_star[None, :], nsum[None, :],
                              c)
    return out[0]
