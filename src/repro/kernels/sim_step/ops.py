"""Dispatch + delivery-tensor precompute for the fused simulator step.

The fused fast path rests on two observations about the scan engine's
per-step pipeline on the `Quadratic` testbed:

  1. *Delivery is schedule-determined.*  For the fused relaxation kinds the
     (p, p) "who receives whose gradient" matrices depend only on the
     pre-drawn oblivious-adversary schedule — crash times and hear draws
     for ``crash``/``crash_subst``, drop draws for ``elastic_variance`` —
     never on the iterates.  So the whole run's delivery tensors are built
     in ONE vectorized pass over T before the scan
     (:func:`delivery_tensors`), and the scan step degenerates to the fused
     kernel call: the ~10 small mask/select ops per step that dominate at
     d ~ 256 disappear from the loop body.

  2. *Everything applied is linear in the gradient panel.*  The x-row, the
     p view-rows and (for the 1-step elastic scheduler) the p defer-rows
     are all rows of ``U @ G`` for one stacked (1+p(+p), p) matrix — one
     MXU matmul instead of three.

``impl`` dispatch: ``"kernel"`` is the Pallas TPU kernel (`kernel.py`,
interpret mode off-TPU — used by the parity suite), ``"ref"`` the fused
jnp oracle (`ref.py`), ``"auto"`` picks the kernel on TPU and the oracle
elsewhere (same math; the oracle avoids pure interpreter overhead on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sim_step import kernel as K
from repro.kernels.sim_step import ref as R

#: Relaxation kinds with a fused step.  ``sync`` collapses to one matvec
#: (all views equal x exactly); the others are delivery-tensor kinds.
FUSED_KINDS = ("sync", "crash", "crash_subst", "elastic_variance")


def supports_fused(problem, relax) -> bool:
    """Fused path needs a quadratic-structured problem (dense ``A`` /
    ``x_star`` sim data + presampleable noise) and a fused kind."""
    if relax.kind not in FUSED_KINDS:
        return False
    if not hasattr(problem, "sim_data") or \
            not hasattr(problem, "presample_from_data"):
        return False
    data = problem.sim_data()
    return "A" in data and "x_star" in data


def _resolve_impl(impl: str):
    """-> (use_kernel, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        return on_tpu, False
    if impl == "kernel":
        return True, not on_tpu
    if impl == "ref":
        return False, False
    raise ValueError(impl)


def delivery_tensors(kind: str, p: int, T: int, per_step: dict,
                     per_run: dict, knobs: dict):
    """Precompute the whole run's delivery tensors, vectorized over T.

    Returns (U (T, m, p) float32, new_alive (T, p) bool or None).  Row 0 of
    each U[t] weights the x update, rows 1..p the view updates (rows of
    dead workers are zero, so no masking pass is needed downstream), rows
    p+1..2p (``elastic_variance`` only) the deferred-correction update.
    The step scale alpha/p is NOT folded in here — callers scale U once.
    """
    eye = jnp.eye(p, dtype=bool)
    if kind in ("crash", "crash_subst"):
        ts = jnp.arange(T)[:, None]
        crash_step = per_run["crash_step"]               # (p,)
        alive = crash_step[None, :] >= ts                # (T, p)
        crashing = crash_step[None, :] == ts
        new_alive = alive & ~crashing
        base = alive[:, :, None] & alive[:, None, :]
        heard = (per_run["hear_u"].T[None] < 0.5) \
            & new_alive[:, :, None] & ~eye[None]
        recv = jnp.where(crashing[:, None, :], heard, base)
        in_recv = jnp.any(recv, axis=1)                  # (T, p)
        w_v = recv.astype(jnp.float32) * new_alive[:, :, None]
        if kind == "crash_subst":
            missed = jnp.sum((~recv) & in_recv[:, None, :], axis=2)
            w_v = w_v + eye[None] * (
                missed.astype(jnp.float32) * new_alive)[:, :, None]
        u = jnp.concatenate(
            [in_recv.astype(jnp.float32)[:, None], w_v], axis=1)
        return u, new_alive
    if kind == "elastic_variance":
        drop = (per_step["drop_u"] < knobs["drop_prob"]) & ~eye[None]
        nd = jnp.sum(drop, axis=2).astype(jnp.float32)   # (T, p)
        diag_nd = eye[None] * nd[:, :, None]
        w_v = jnp.ones((T, p, p), jnp.float32) + diag_nd - drop
        w_d = drop.astype(jnp.float32) - diag_nd
        u = jnp.concatenate(
            [jnp.ones((T, 1, p), jnp.float32), w_v, w_d], axis=1)
        return u, None
    raise ValueError(f"no delivery tensor for kind {kind!r}")


def fused_delivery_step(v, x, a, x_star, noise, u, defer=None, *,
                        impl: str = "auto", block_d: int = 256):
    """One fused step.  v (p, d); x (d,); u (m, p) with the step scale
    already folded in; defer (p, d) or None.  Returns (x', v'[, defer'])
    with x' (d,)."""
    use_kernel, interpret = _resolve_impl(impl)
    x2, xs2 = x[None, :], x_star[None, :]
    if use_kernel:
        out = K.delivery_step(v, x2, a, xs2, noise, u, defer,
                              block_d=block_d, has_defer=defer is not None,
                              interpret=interpret)
    else:
        out = R.delivery_step_ref(v, x2, a, xs2, noise, u, defer)
    return (out[0][0], *out[1:])


def fused_sync_step(x, a, x_star, nsum, c, *, impl: str = "auto",
                    block_d: int = 256):
    """One fused sync step.  x, x_star, nsum (d,); c scalar.  nsum must be
    pre-scaled by alpha/p; c is the collapsed gradient weight alpha."""
    use_kernel, interpret = _resolve_impl(impl)
    if use_kernel:
        out = K.sync_step(x[None, :], a, x_star[None, :], nsum[None, :],
                          jnp.reshape(c, (1, 1)), block_d=block_d,
                          interpret=interpret)
    else:
        out = R.sync_step_ref(x[None, :], a, x_star[None, :], nsum[None, :],
                              c)
    return out[0]
