"""Fused one-bit (sign/mean) quantization + error-feedback Pallas TPU kernel.

Implements Eq. 30 per VMEM row block: [Q(w)]_i = mean over i's sign class,
with the error memory update fused (Alg 6). The wire payload is a *packed*
uint8 bitmap (8 signs/byte — the XLA fallback ships 1 byte/sign, so the
kernel is an 8x wire saving on top of the 32x vs f32) plus two f32 means per
row.

Tiling: (BM, R) row blocks; all reductions are row-wise on the VPU over
(8, 128)-lane tiles; the bit-pack is a reshape + weighted sum along the
trailing 8-wide axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onebit_ef_kernel(g_ref, e_ref, packed_ref, means_ref, err_ref):
    w = e_ref[...] + g_ref[...].astype(jnp.float32)      # (BM, R)
    bm, r = w.shape
    pos = w >= 0.0
    n_pos = jnp.maximum(jnp.sum(pos, axis=1), 1)
    n_neg = jnp.maximum(r - jnp.sum(pos, axis=1), 1)
    mean_pos = jnp.sum(jnp.where(pos, w, 0.0), axis=1) / n_pos
    mean_neg = jnp.sum(jnp.where(pos, 0.0, w), axis=1) / n_neg
    means_ref[:, 0] = mean_pos
    means_ref[:, 1] = mean_neg
    bits = pos.reshape(bm, r // 8, 8).astype(jnp.uint8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8))
    packed_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)
    q = jnp.where(pos, mean_pos[:, None], mean_neg[:, None])
    err_ref[...] = w - q


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def onebit_ef(g: jax.Array, err: jax.Array, *, block_rows: int = 8,
              interpret: bool = False):
    """g, err: (M, R) with R % 8 == 0. Returns (packed (M, R/8) u8,
    means (M, 2) f32, new_err (M, R) f32)."""
    m, r = g.shape
    assert r % 8 == 0, r
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        _onebit_ef_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, r // 8), lambda i: (i, 0)),
            pl.BlockSpec((bm, 2), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, r // 8), jnp.uint8),
            jax.ShapeDtypeStruct((m, 2), jnp.float32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
        ],
        interpret=interpret,
    )(g, err)
