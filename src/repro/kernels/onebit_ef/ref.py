"""Pure-jnp oracle for the onebit_ef kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def onebit_ef_ref(g: jax.Array, err: jax.Array):
    w = err + g.astype(jnp.float32)                      # (M, R)
    m, r = w.shape
    pos = w >= 0.0
    n_pos = jnp.maximum(jnp.sum(pos, axis=1), 1)
    n_neg = jnp.maximum(r - jnp.sum(pos, axis=1), 1)
    mean_pos = jnp.sum(jnp.where(pos, w, 0.0), axis=1) / n_pos
    mean_neg = jnp.sum(jnp.where(pos, 0.0, w), axis=1) / n_neg
    bits = pos.reshape(m, r // 8, 8).astype(jnp.uint8)
    packed = jnp.sum(bits * (2 ** jnp.arange(8, dtype=jnp.uint8)), axis=-1,
                     dtype=jnp.uint8)
    means = jnp.stack([mean_pos, mean_neg], axis=1)
    q = jnp.where(pos, mean_pos[:, None], mean_neg[:, None])
    return packed, means, w - q


def unpack(packed: jax.Array, means: jax.Array, r: int) -> jax.Array:
    """Reconstruct Q(w) from the wire payload."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    pos = bits.reshape(*packed.shape[:-1], -1)[..., :r].astype(bool)
    return jnp.where(pos, means[..., 0:1], means[..., 1:2])
