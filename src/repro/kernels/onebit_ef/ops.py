"""Jit wrappers for onebit_ef."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.onebit_ef.kernel import onebit_ef
from repro.kernels.onebit_ef.ref import onebit_ef_ref, unpack


def compress_leaf(g2d: jax.Array, err2d: jax.Array,
                  use_kernel: bool = True, interpret: bool = True):
    m, r = g2d.shape
    if use_kernel and m % 8 == 0 and r % 8 == 0:
        return onebit_ef(g2d, err2d, interpret=interpret)
    return onebit_ef_ref(g2d, err2d)


def decompress_sum(packed: jax.Array, means: jax.Array, r: int) -> jax.Array:
    """packed (P, M, R/8), means (P, M, 2) -> dense sum (M, R)."""
    q = unpack(packed, means, r)                         # (P, M, R)
    return jnp.sum(q, axis=0)
