from repro.kernels.onebit_ef.kernel import onebit_ef  # noqa: F401
from repro.kernels.onebit_ef.ref import onebit_ef_ref, unpack  # noqa: F401
from repro.kernels.onebit_ef.ops import compress_leaf, decompress_sum  # noqa: F401
