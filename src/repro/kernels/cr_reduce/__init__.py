from repro.kernels.cr_reduce.kernel import (topk_cr_reduce,  # noqa: F401
                                            topk_cr_deposit,
                                            onebit_cr_reduce,
                                            onebit_cr_deposit)
from repro.kernels.cr_reduce.ref import (topk_cr_reduce_ref,  # noqa: F401
                                         topk_cr_deposit_ref,
                                         onebit_cr_reduce_ref,
                                         onebit_cr_deposit_ref)
from repro.kernels.cr_reduce.ops import (topk_reduce,  # noqa: F401
                                         topk_deposit,
                                         onebit_reduce,
                                         onebit_deposit)
