"""Dispatch for the fused compress-then-reduce ops.

``impl`` dispatch mirrors `kernels.sim_step.ops`: ``"kernel"`` is the
Pallas TPU kernel (`kernel.py`, interpret mode off-TPU — used by the
parity suite), ``"ref"`` the jnp oracle (`ref.py`), ``"auto"`` picks the
kernel on TPU and the oracle elsewhere (same math; the oracle avoids pure
interpreter overhead on CPU).

Also hosts the row-space *compress* dispatch the bounded-staleness engine
uses to build wire payloads without densifying (the compress half of
compress-then-reduce): top-k routes through the `kernels.topk_ef` family,
one-bit computes the sign/mean wire form (bool bitmap + two means per
row) — the unpacked form the reduce kernels consume and
`core.scheduler._leaf_onebit_sync` already ships; the 8x-packed
`kernels.onebit_ef` variant stays a TPU-only wire optimization
(ROADMAP: toolchain bump) because packing requires lane-aligned rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cr_reduce.kernel import (onebit_cr_deposit,
                                            onebit_cr_reduce,
                                            topk_cr_deposit, topk_cr_reduce)
from repro.kernels.cr_reduce.ref import (onebit_cr_deposit_ref,
                                         onebit_cr_reduce_ref,
                                         topk_cr_deposit_ref,
                                         topk_cr_reduce_ref)


def _resolve_impl(impl: str):
    """-> (use_kernel, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        return on_tpu, False
    if impl == "kernel":
        return True, not on_tpu
    if impl == "ref":
        return False, False
    raise ValueError(impl)


def topk_reduce(vals: jax.Array, idx: jax.Array, weights: jax.Array,
                r: int, *, impl: str = "auto",
                block_rows: int = 8) -> jax.Array:
    """Weighted scatter-sum of S sparse messages: vals/idx (S, M, k),
    weights (S,) -> dense (M, R) f32."""
    use_kernel, interpret = _resolve_impl(impl)
    s, m, k = vals.shape
    if use_kernel and m > 0 and m % block_rows == 0 and s > 0 and k > 0 \
            and r > 0:
        return topk_cr_reduce(vals, idx, weights, r=r,
                              block_rows=block_rows, interpret=interpret)
    return topk_cr_reduce_ref(vals, idx, weights, r)


def onebit_reduce(pos: jax.Array, means: jax.Array, weights: jax.Array,
                  *, impl: str = "auto", block_rows: int = 8) -> jax.Array:
    """Weighted sum of S sign/mean messages: pos (S, M, R), means (S, M, 2),
    weights (S,) -> dense (M, R) f32."""
    use_kernel, interpret = _resolve_impl(impl)
    s, m, r = pos.shape
    if use_kernel and m > 0 and m % block_rows == 0 and s > 0 and r > 0:
        return onebit_cr_reduce(pos, means, weights,
                                block_rows=block_rows, interpret=interpret)
    return onebit_cr_reduce_ref(pos, means, weights, r)


def topk_deposit(acc: jax.Array, vals: jax.Array, idx: jax.Array,
                 slots: jax.Array, weights: jax.Array, *,
                 impl: str = "auto", block_rows: int = 8) -> jax.Array:
    """Fused decompress-deposit of S sparse messages into their delay-ring
    slots: acc (cap, M, R) f32, vals/idx (S, M, k), slots/weights (S,)
    -> updated acc (one scatter for the whole panel; zero weights no-op)."""
    use_kernel, interpret = _resolve_impl(impl)
    s, m, k = vals.shape
    if use_kernel and m > 0 and m % block_rows == 0 and s > 0 and k > 0 \
            and acc.size > 0:
        return topk_cr_deposit(acc, vals, idx, slots, weights,
                               block_rows=block_rows, interpret=interpret)
    return topk_cr_deposit_ref(acc, vals, idx, slots, weights)


def onebit_deposit(acc: jax.Array, pos: jax.Array, means: jax.Array,
                   slots: jax.Array, weights: jax.Array, *,
                   impl: str = "auto", block_rows: int = 8) -> jax.Array:
    """Fused decompress-deposit of S sign/mean messages into their slots:
    acc (cap, M, R) f32, pos (S, M, R), means (S, M, 2), slots/weights (S,)
    -> updated acc."""
    use_kernel, interpret = _resolve_impl(impl)
    s, m, r = pos.shape
    if use_kernel and m > 0 and m % block_rows == 0 and s > 0 and r > 0 \
            and acc.size > 0:
        return onebit_cr_deposit(acc, pos, means, slots, weights,
                                 block_rows=block_rows, interpret=interpret)
    return onebit_cr_deposit_ref(acc, pos, means, slots, weights)


# ---------------------------------------------------------------------------
# row-space compress (the other half; wire forms the reduce ops consume)
# ---------------------------------------------------------------------------

def topk_compress_rows(rows: jax.Array, err_rows: jax.Array, ratio: float,
                       *, impl: str = "auto"):
    """(M, R) rows + EF residual -> (vals (M, k) f32, idx (M, k) i32,
    new_err (M, R) f32), k = max(1, round(R * ratio)) — the compact wire
    payload, never densified."""
    from repro.kernels.topk_ef.ops import compress_leaf
    use_kernel, interpret = _resolve_impl(impl)
    return compress_leaf(rows, err_rows, ratio, use_kernel, interpret)


def onebit_compress_rows(rows: jax.Array, err_rows: jax.Array):
    """(M, R) rows + EF residual -> (pos (M, R) bool, means (M, 2) f32,
    new_err (M, R) f32) — Eq. 30 per row, in the unpacked wire form."""
    w = err_rows + rows.astype(jnp.float32)
    m, r = w.shape
    pos = w >= 0.0
    n_pos = jnp.maximum(jnp.sum(pos, axis=1), 1)
    n_neg = jnp.maximum(r - jnp.sum(pos, axis=1), 1)
    mean_pos = jnp.sum(jnp.where(pos, w, 0.0), axis=1) / n_pos
    mean_neg = jnp.sum(jnp.where(pos, 0.0, w), axis=1) / n_neg
    means = jnp.stack([mean_pos, mean_neg], axis=1)
    q = jnp.where(pos, mean_pos[:, None], mean_neg[:, None])
    return pos, means, w - q
