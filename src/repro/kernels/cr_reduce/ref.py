"""Pure-jnp oracle for the fused compress-then-reduce kernels.

Both oracles reduce a *panel* of S compressed messages straight to the
dense weighted sum — no per-message dense intermediate is ever
materialized at (S, M, R), which is exactly the contract the Pallas
kernels implement blockwise in VMEM.  ``weights`` carries everything the
caller wants folded into the reduction: the 0/1 delivery mask of the
bounded-staleness engine, the 1/n of a mean, crash-substitution rescales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_cr_reduce_ref(vals: jax.Array, idx: jax.Array, weights: jax.Array,
                       r: int) -> jax.Array:
    """Weighted scatter-sum of S sparse messages.

    vals (S, M, k) float, idx (S, M, k) int32 (per-row positions in
    [0, r)), weights (S,) float -> dense (M, R) float32.  Duplicate
    positions — within one message or across messages — accumulate.
    """
    s, m, k = vals.shape
    if s == 0 or m == 0 or r == 0 or k == 0:
        return jnp.zeros((m, r), jnp.float32)
    w = vals.astype(jnp.float32) * weights.astype(jnp.float32)[:, None, None]
    return jnp.zeros((m, r), jnp.float32).at[
        jnp.arange(m)[None, :, None], idx].add(w)


def onebit_cr_reduce_ref(pos: jax.Array, means: jax.Array,
                         weights: jax.Array, r: int) -> jax.Array:
    """Weighted sum of S sign/mean messages (Eq. 30 wire form).

    pos (S, M, R) bool, means (S, M, 2) float32 (mean_pos, mean_neg),
    weights (S,) float -> dense (M, R) float32.
    """
    s, m, _ = pos.shape
    if s == 0 or m == 0 or r == 0:
        return jnp.zeros((m, r), jnp.float32)
    q = jnp.where(pos, means[..., 0:1], means[..., 1:2])
    return jnp.sum(q * weights.astype(jnp.float32)[:, None, None], axis=0,
                   dtype=jnp.float32)


def topk_cr_deposit_ref(acc: jax.Array, vals: jax.Array, idx: jax.Array,
                        slots: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted scatter of S sparse messages into ring slots.

    acc (cap, M, R) f32, vals/idx (S, M, k), slots (S,) int32 in
    [0, cap), weights (S,) float -> updated acc.  Message ``i`` lands in
    slot ``slots[i]``; messages sharing a slot accumulate (also with the
    slot's prior content), and a zero weight writes zeros — the
    delivery-ring deposit of the bounded-staleness engine, fused with the
    decompression.
    """
    s, m, k = vals.shape
    if s == 0 or m == 0 or k == 0 or acc.size == 0:
        return acc
    w = vals.astype(jnp.float32) * weights.astype(jnp.float32)[:, None, None]
    return acc.at[slots[:, None, None], jnp.arange(m)[None, :, None],
                  idx].add(w)


def onebit_cr_deposit_ref(acc: jax.Array, pos: jax.Array, means: jax.Array,
                          slots: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted slot deposit of S sign/mean messages.

    acc (cap, M, R) f32, pos (S, M, R) bool, means (S, M, 2) f32,
    slots (S,) int32, weights (S,) float -> updated acc (duplicate slots
    accumulate).
    """
    s, m, _ = pos.shape
    if s == 0 or m == 0 or acc.size == 0:
        return acc
    q = jnp.where(pos, means[..., 0:1], means[..., 1:2])
    return acc.at[slots].add(
        q * weights.astype(jnp.float32)[:, None, None])
