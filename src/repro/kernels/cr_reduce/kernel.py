"""Fused compress-then-reduce Pallas TPU kernels.

The other half of the `topk_ef` / `onebit_ef` compression kernels: those
produce the compact wire payloads, these consume a *panel* of S such
payloads (the all-gathered messages resident in the bounded-staleness
engine's delivery rings) and reduce them straight to the dense weighted
sum.  The panel is never densified to (S, M, R) in HBM — each grid step
holds one (BM, R) accumulator in VMEM and streams the S messages' compact
payloads through it, so the reduction's HBM traffic is the compressed
bytes plus one dense output write.

``weights (S, 1)`` folds the caller's per-message factors into the same
pass: the engine's 0/1 delivery mask (which message is due this step),
the 1/n of the mean, crash-substitution rescales.  A zero weight makes a
message a no-op, so masking costs no branch.

Tiling: (BM, R) row blocks as in `kernels/topk_ef`; the top-k scatter is
k iterations of a row-indexed add on the VPU per message, the one-bit
accumulate is a select + axpy per message.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_cr_kernel(vals_ref, idx_ref, w_ref, out_ref, *, s: int, k: int):
    bm, r = out_ref.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)[:, 0]

    def per_message(si, acc):
        w = w_ref[si, 0]

        def per_entry(j, acc):
            col = idx_ref[si, :, j]                       # (BM,)
            v = vals_ref[si, :, j].astype(jnp.float32) * w
            return acc.at[rows, col].add(v)

        return jax.lax.fori_loop(0, k, per_entry, acc)

    out_ref[...] = jax.lax.fori_loop(
        0, s, per_message, jnp.zeros((bm, r), jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("r", "block_rows", "interpret"))
def topk_cr_reduce(vals: jax.Array, idx: jax.Array, weights: jax.Array, *,
                   r: int, block_rows: int = 8, interpret: bool = False):
    """vals (S, M, k), idx (S, M, k) i32, weights (S,) -> dense (M, R) f32
    weighted scatter-sum of the S sparse messages."""
    s, m, k = vals.shape
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_topk_cr_kernel, s=s, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, bm, k), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, k), lambda i: (0, i, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=interpret,
    )(vals, idx, weights.reshape(s, 1).astype(jnp.float32))


def _topk_cr_deposit_kernel(acc_ref, vals_ref, idx_ref, slots_ref, w_ref,
                            out_ref, *, s: int, k: int):
    cap, bm, r = out_ref.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)[:, 0]

    def per_message(si, acc):
        slot = slots_ref[si, 0]
        w = w_ref[si, 0]

        def per_entry(j, acc):
            col = idx_ref[si, :, j]                       # (BM,)
            v = vals_ref[si, :, j].astype(jnp.float32) * w
            return acc.at[slot, rows, col].add(v)

        return jax.lax.fori_loop(0, k, per_entry, acc)

    out_ref[...] = jax.lax.fori_loop(0, s, per_message, acc_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def topk_cr_deposit(acc: jax.Array, vals: jax.Array, idx: jax.Array,
                    slots: jax.Array, weights: jax.Array, *,
                    block_rows: int = 8, interpret: bool = False):
    """Fused decompress-deposit: scatter S sparse messages (vals/idx
    (S, M, k), weights (S,)) into their delay-ring slots (slots (S,)) of
    acc (cap, M, R) f32 — the ring block stays resident in VMEM while the
    S compact messages stream through it."""
    s, m, k = vals.shape
    cap, _, r = acc.shape
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_topk_cr_deposit_kernel, s=s, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, bm, r), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, k), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, k), lambda i: (0, i, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cap, bm, r), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((cap, m, r), jnp.float32),
        interpret=interpret,
    )(acc, vals, idx, slots.reshape(s, 1).astype(jnp.int32),
      weights.reshape(s, 1).astype(jnp.float32))


def _onebit_cr_deposit_kernel(acc_ref, pos_ref, means_ref, slots_ref,
                              w_ref, out_ref, *, s: int):
    def per_message(si, acc):
        slot = slots_ref[si, 0]
        mean_pos = means_ref[si, :, 0][:, None]
        mean_neg = means_ref[si, :, 1][:, None]
        q = jnp.where(pos_ref[si], mean_pos, mean_neg) * w_ref[si, 0]
        return acc.at[slot].add(q)

    out_ref[...] = jax.lax.fori_loop(0, s, per_message, acc_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def onebit_cr_deposit(acc: jax.Array, pos: jax.Array, means: jax.Array,
                      slots: jax.Array, weights: jax.Array, *,
                      block_rows: int = 8, interpret: bool = False):
    """Fused decompress-deposit of S sign/mean messages (pos (S, M, R),
    means (S, M, 2), weights (S,)) into their slots of acc (cap, M, R)."""
    s, m, r = pos.shape
    cap = acc.shape[0]
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_onebit_cr_deposit_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, bm, r), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, r), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, 2), lambda i: (0, i, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cap, bm, r), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((cap, m, r), jnp.float32),
        interpret=interpret,
    )(acc, pos, means.astype(jnp.float32),
      slots.reshape(s, 1).astype(jnp.int32),
      weights.reshape(s, 1).astype(jnp.float32))


def _onebit_cr_kernel(pos_ref, means_ref, w_ref, out_ref, *, s: int):
    bm, r = out_ref.shape

    def per_message(si, acc):
        pos = pos_ref[si]                                 # (BM, R) bool
        mean_pos = means_ref[si, :, 0][:, None]
        mean_neg = means_ref[si, :, 1][:, None]
        q = jnp.where(pos, mean_pos, mean_neg)
        return acc + q * w_ref[si, 0]

    out_ref[...] = jax.lax.fori_loop(
        0, s, per_message, jnp.zeros((bm, r), jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def onebit_cr_reduce(pos: jax.Array, means: jax.Array, weights: jax.Array,
                     *, block_rows: int = 8, interpret: bool = False):
    """pos (S, M, R) bool, means (S, M, 2) f32, weights (S,) -> dense
    (M, R) f32 weighted sum of the S sign/mean messages."""
    s, m, r = pos.shape
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_onebit_cr_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, bm, r), lambda i: (0, i, 0)),
            pl.BlockSpec((s, bm, 2), lambda i: (0, i, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=interpret,
    )(pos, means.astype(jnp.float32),
      weights.reshape(s, 1).astype(jnp.float32))
