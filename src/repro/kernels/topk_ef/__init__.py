from repro.kernels.topk_ef.kernel import topk_ef  # noqa: F401
from repro.kernels.topk_ef.ref import topk_ef_ref, q_dense  # noqa: F401
from repro.kernels.topk_ef.ops import compress_leaf, decompress_sum  # noqa: F401
