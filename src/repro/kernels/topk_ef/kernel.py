"""Fused block-local Top-K + error-feedback Pallas TPU kernel.

The compression hot-spot of the paper's technique: every step, each worker
compresses its (residual + gradient) before the wire collective (Alg 6).

TPU adaptation (vs the GPU radix-select ports): there is no cross-lane
shuffle on TPU, so selection is *row-local within a VMEM block*: the grid
tiles the (M, R) operand into (BM, R) row blocks resident in VMEM, and per
row the top-k is found by k iterations of (argmax, mask) on the VPU — k is
small (R * ratio), so this is k * O(R) vector work entirely in VMEM, fused
with the error-feedback update (err' = w - Q(w)) so ``w`` never round-trips
to HBM.

Block-local selection is a *stricter* contraction than global top-k with the
same per-row ratio (property-tested in tests/test_kernels.py), so Lemma 18's
elastic-consistency bound applies with the same gamma.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_ef_kernel(g_ref, e_ref, vals_ref, idx_ref, err_ref, *, k: int):
    w = e_ref[...] + g_ref[...].astype(jnp.float32)      # (BM, R) in VMEM
    bm, r = w.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)[:, 0]

    def body(i, carry):
        absw, mask = carry
        am = jnp.argmax(absw, axis=1).astype(jnp.int32)  # (BM,)
        vals_ref[:, i] = w[rows, am]
        idx_ref[:, i] = am
        absw = absw.at[rows, am].set(-jnp.inf)
        mask = mask.at[rows, am].set(True)
        return absw, mask

    absw = jnp.abs(w)
    mask0 = jnp.zeros(w.shape, jnp.bool_)
    _, mask = jax.lax.fori_loop(0, k, body, (absw, mask0))
    err_ref[...] = jnp.where(mask, 0.0, w)               # w - Q(w)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_ef(g: jax.Array, err: jax.Array, *, k: int, block_rows: int = 8,
            interpret: bool = False):
    """g, err: (M, R). Returns (values (M,k) f32, indices (M,k) i32,
    new_err (M,R) f32)."""
    m, r = g.shape
    bm = min(block_rows, m)
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m, r), jnp.float32),
        ],
        interpret=interpret,
    )(g, err)
