"""Jit wrappers for topk_ef: leaf-level compress/decompress used by the
production sync strategy (rows = model-sharded dims, cols compressed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_ef.kernel import topk_ef
from repro.kernels.topk_ef.ref import topk_ef_ref


def compress_leaf(g2d: jax.Array, err2d: jax.Array, ratio: float,
                  use_kernel: bool = True, interpret: bool = True):
    """(M, R) leaf -> (vals, idx, new_err). ``interpret=True`` on CPU."""
    m, r = g2d.shape
    k = max(1, int(round(r * ratio)))
    if use_kernel and m % 8 == 0:
        return topk_ef(g2d, err2d, k=k, interpret=interpret)
    return topk_ef_ref(g2d, err2d, k=k)


def decompress_sum(vals: jax.Array, idx: jax.Array, r: int) -> jax.Array:
    """Sum per-worker sparse payloads: vals/idx (P, M, k) -> dense (M, R)."""
    p, m, k = vals.shape
    dense = jnp.zeros((m, r), jnp.float32)

    def add_one(dense, pv):
        v, i = pv
        return dense.at[jnp.arange(m)[:, None], i].add(v), None

    dense, _ = jax.lax.scan(add_one, dense, (vals, idx))
    return dense
