"""Pure-jnp oracle for the topk_ef kernel (identical row-local semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ef_ref(g: jax.Array, err: jax.Array, *, k: int):
    w = err + g.astype(jnp.float32)                      # (M, R)
    _, idx = jax.lax.top_k(jnp.abs(w), k)                # (M, k)
    vals = jnp.take_along_axis(w, idx, axis=1)
    mask = jnp.zeros(w.shape, bool)
    mask = mask.at[jnp.arange(w.shape[0])[:, None], idx].set(True)
    new_err = jnp.where(mask, 0.0, w)
    return vals, idx, new_err


def q_dense(g, err, *, k):
    """Dense Q(w) for contraction-property tests."""
    vals, idx, new_err = topk_ef_ref(g, err, k=k)
    w = err + g.astype(jnp.float32)
    return w - new_err
