from repro.kernels.ssd.kernel import ssd_chunked_kernel  # noqa: F401
from repro.kernels.ssd.ref import ssd_ref  # noqa: F401
from repro.kernels.ssd.ops import ssd  # noqa: F401
