"""Oracle for the SSD kernel: the pure-jnp chunked scan the model uses."""
from repro.models.mamba2 import ssd_chunked


def ssd_ref(xh, a, bmat, cmat):
    return ssd_chunked(xh, a, bmat, cmat, None)
