"""Fused Mamba2/SSD chunk-scan Pallas TPU kernel.

The §Perf analysis of zamba2 x train_4k showed the SSD path is memory-bound:
the XLA lowering materializes the (C, C) decay/attention matrices and the
f32 state updates in HBM every chunk. This kernel runs the whole chunked
recurrence for one (batch, head) with the chunk tensors and the running
state resident in VMEM:

  grid (B, H, n_chunks), chunk dimension sequential; per step
    cum   = cumsum(a_chunk)                      (C,)
    L     = tril(exp(cum_i - cum_j))             (C, C)   VMEM only
    A     = (C_c @ B_c^T) * L                    (C, C)   VMEM only
    y     = A @ X_c + exp(cum) * (C_c @ S^T)     (C, hd)
    S     = exp(cum_C) * S + X_c^T @ (B_c * exp(cum_C - cum))   (hd, N)

HBM traffic: one read of X/a/B/C, one write of y and the final state — the
(C,C) tensors never leave VMEM (the XLA form writes+reads them 4x with
remat). All matmuls are MXU-shaped (C=128, hd, N multiples of 8/128 where
the config allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import compiler_params


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, s_scr,
                *, chunk: int):
    j = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr[...])

    xc = x_ref[0, :, 0].astype(jnp.float32)       # (C, hd)
    ac = a_ref[0, :, 0].astype(jnp.float32)       # (C,)
    bc = b_ref[0].astype(jnp.float32)             # (C, N)
    cc = c_ref[0].astype(jnp.float32)             # (C, N)

    cum = jnp.cumsum(ac)                          # (C,)
    ldiff = cum[:, None] - cum[None, :]           # (C, C)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    lmat = jnp.where(mask, jnp.exp(ldiff), 0.0)
    amat = (cc @ bc.T) * lmat                     # (C, C), VMEM-resident
    state = s_scr[...]                            # (hd, N)
    y = amat @ xc                                 # (C, hd)
    y = y + jnp.exp(cum)[:, None] * (cc @ state.T)
    decay_rest = jnp.exp(cum[-1] - cum)           # (C,)
    kd = bc * decay_rest[:, None]                 # (C, N)
    s_scr[...] = jnp.exp(cum[-1]) * state + xc.T @ kd
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(j == nt - 1)
    def _finish():
        s_out_ref[0, 0] = s_scr[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(xh: jax.Array, a: jax.Array, bmat: jax.Array,
                       cmat: jax.Array, *, chunk: int = 128,
                       interpret: bool = False):
    """xh: (B, T, H, hd) dt-scaled inputs; a: (B, T, H) log-decays (<= 0);
    bmat/cmat: (B, T, N). Returns (y (B,T,H,hd), state (B,H,hd,N) f32).
    Zero initial state (the train/prefill case)."""
    b, t, h, hd = xh.shape
    n = bmat.shape[-1]
    c = min(chunk, t)
    assert t % c == 0, (t, c)
    grid = (b, h, t // c)
    kernel = functools.partial(_ssd_kernel, chunk=c)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, 1, hd), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, c, 1), lambda bi, hi, ti: (bi, ti, hi)),
            pl.BlockSpec((1, c, n), lambda bi, hi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, c, n), lambda bi, hi, ti: (bi, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, hd), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, 1, hd, n), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), xh.dtype),
            jax.ShapeDtypeStruct((b, h, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xh, a, bmat, cmat)
    return y, state
