"""Jit wrapper: kernel (interpret on CPU, Mosaic on TPU) vs jnp oracle."""
from __future__ import annotations

from repro.kernels.ssd.kernel import ssd_chunked_kernel
from repro.kernels.ssd.ref import ssd_ref


def ssd(xh, a, bmat, cmat, *, use_kernel: bool = True,
        interpret: bool = True, chunk: int = 128):
    t = xh.shape[1]
    if use_kernel and t % min(chunk, t) == 0:
        return ssd_chunked_kernel(xh, a, bmat, cmat,
                                  chunk=chunk, interpret=interpret)
    return ssd_ref(xh, a, bmat, cmat)
