"""Compat shim for the Pallas-TPU compiler-params API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
the 0.4/0.5 series; every kernel routes through :func:`compiler_params` so
the rename is absorbed in exactly one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams — unsupported jax version for these kernels")
    return cls(**kwargs)
