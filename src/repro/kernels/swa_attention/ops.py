"""Jit wrapper selecting kernel vs oracle (kernel in interpret mode on CPU;
compiled Mosaic on real TPU)."""
from __future__ import annotations

import jax

from repro.kernels.swa_attention.kernel import swa_decode_attention
from repro.kernels.swa_attention.ref import swa_decode_ref


def decode_attention(q, k_cache, v_cache, pos, base=None, *, window: int = 0,
                     use_kernel: bool = True, interpret: bool = True):
    t = k_cache.shape[1]
    if use_kernel and t % 512 == 0:
        return swa_decode_attention(q, k_cache, v_cache, pos, base,
                                    window=window, interpret=interpret)
    if use_kernel and t % 128 == 0:
        return swa_decode_attention(q, k_cache, v_cache, pos, base,
                                    window=window, block_t=128,
                                    interpret=interpret)
    return swa_decode_ref(q, k_cache, v_cache, pos, base, window=window)
