from repro.kernels.swa_attention.kernel import swa_decode_attention  # noqa: F401
from repro.kernels.swa_attention.ref import swa_decode_ref  # noqa: F401
from repro.kernels.swa_attention.ops import decode_attention  # noqa: F401
