"""Pure-jnp oracle for sliding-window decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_decode_ref(q, k_cache, v_cache, pos, *, window: int = 0):
    """q: (B, KV, G, D); caches (B, T, KV, D); pos scalar."""
    b, nkv, g, d = q.shape
    t = k_cache.shape[1]
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (d ** -0.5)
    key_pos = jnp.arange(t)
    valid = key_pos <= pos
    if window:
        valid &= (pos - key_pos) < window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
