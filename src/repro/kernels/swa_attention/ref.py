"""Pure-jnp oracle for sliding-window decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_decode_ref(q, k_cache, v_cache, pos, base=None, *, window: int = 0):
    """q: (B, KV, G, D); caches (B, T, KV, D); pos scalar or (B,); base
    optional (B,) absolute position of each row's key 0."""
    b, nkv, g, d = q.shape
    t = k_cache.shape[1]
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (d ** -0.5)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    base = (jnp.zeros((b,), jnp.int32) if base is None
            else jnp.broadcast_to(jnp.asarray(base, jnp.int32), (b,)))
    key_pos = base[:, None] + jnp.arange(t)[None]          # (B, T)
    valid = key_pos <= pos[:, None]
    if window:
        valid &= (pos[:, None] - key_pos) < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
