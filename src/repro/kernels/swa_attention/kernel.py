"""Flash-style sliding-window decode attention Pallas TPU kernel.

The serving hot path for the decode shapes (decode_32k / long_500k): one
query token per sequence attends over a long KV cache. The XLA fallback
materializes the (H, T) score row in HBM; this kernel streams KV blocks
through VMEM with online-softmax accumulation, so HBM traffic is exactly one
read of the (window of the) cache and the scores never leave VMEM — the
memory-roofline win on a workload that is purely HBM-bound.

Grid: (B, KV_heads, T/BT) with the T dimension sequential ("arbitrary"),
carrying running (max, denom, acc) in VMEM scratch across KV blocks.
Window/causal masking is positional: block j covers keys
[base + j*BT, base + j*BT + BT), valid iff pos - window < key <= pos.

Both ``pos`` and ``base`` may be per-sequence vectors: the paged serving
engine (`repro.serve.engine`) hands the kernel a *window gather* of live
pages per request, so each row's keys start at its own absolute position
``base[b]`` and its query sits at its own ``pos[b]``.  Scalar ``pos`` (the
dense single-position form) is still accepted and broadcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import compiler_params

NEG_INF = -1e30


def _swa_decode_kernel(pos_ref, base_ref, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, block_t: int, window: int,
                       scale: float):
    j = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    pos = pos_ref[0]
    base = base_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (BT, D)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (BT, D)

    key_pos = base + j * block_t + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_t), 1)[0]
    valid = key_pos <= pos
    if window:
        valid &= (pos - key_pos) < window
    s = (q @ k.T) * scale                                # (G, BT)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (G, BT)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + p @ v                    # (G, D)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == nt - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_t",
                                             "interpret"))
def swa_decode_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, pos: jax.Array,
                         base: jax.Array | None = None, *,
                         window: int = 0, block_t: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, D) one token per sequence (G = query heads per kv head);
    k_cache/v_cache: (B, T, KV, D); pos: scalar or (B,) int32 (current
    position(s) — keys at positions <= pos are live); base: optional (B,)
    int32 absolute position of each row's key 0 (paged window gathers).
    Returns (B, KV, G, D)."""
    b, nkv, g, d = q.shape
    t = k_cache.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    grid = (b, nkv, t // bt)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    base_arr = (jnp.zeros((b,), jnp.int32) if base is None
                else jnp.broadcast_to(jnp.asarray(base, jnp.int32), (b,)))
    kernel = functools.partial(_swa_decode_kernel, block_t=bt, window=window,
                               scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ti: (bi,)),
            pl.BlockSpec((1,), lambda bi, hi, ti: (bi,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bt, 1, d), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, bt, 1, d), lambda bi, hi, ti: (bi, ti, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos_arr, base_arr, q, k_cache, v_cache)
