"""Minimal pytree checkpointing (.npz + structure manifest).

Arrays are gathered to host and written atomically; restore rebuilds the
pytree and (optionally) re-shards onto a mesh via ``jax.device_put`` with the
provided shardings. Format: one ``step_<N>.npz`` per step with flattened
``"<idx>"`` keys plus a pickled treedef sidecar.
"""
from __future__ import annotations

import os
import pickle
import tempfile

import jax
import numpy as np


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {str(i): np.asarray(jax.device_get(x)) for i, x in enumerate(leaves)}
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    with open(path + ".treedef", "wb") as f:
        pickle.dump(treedef, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[len("step_"):-len(".npz")])
             for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".npz")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, shardings=None):
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with open(path + ".treedef", "rb") as f:
        treedef = pickle.load(f)
    data = np.load(path)
    leaves = [data[str(i)] for i in range(len(data.files))]
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
