"""Minimal pytree checkpointing (.npz + structure manifest).

Arrays are gathered to host and written atomically; restore rebuilds the
pytree and (optionally) re-shards onto a mesh via ``jax.device_put`` with the
provided shardings. Format: one ``step_<N>.npz`` per step with flattened
``"<idx>"`` keys plus a pickled treedef sidecar.

Crash ordering: the treedef sidecar is replaced into place *before* the
``.npz`` — a kill between the two leaves a sidecar without arrays, which
``latest_step`` (keyed on the ``.npz``) never even sees.  The reverse order
would leave an ``.npz`` whose restore dies on the missing sidecar, which is
exactly the torn state ``latest_step`` additionally skips-and-warns on (a
checkpoint from before this ordering existed, or a sidecar lost to the
filesystem).
"""
from __future__ import annotations

import os
import pickle
import tempfile
import warnings

import jax
import numpy as np


def _atomic_replace(dirname: str, path: str, write_fn) -> None:
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {str(i): np.asarray(jax.device_get(x)) for i, x in enumerate(leaves)}
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    # sidecar FIRST: once the .npz lands, its manifest already exists
    _atomic_replace(ckpt_dir, path + ".treedef",
                    lambda f: pickle.dump(treedef, f))
    _atomic_replace(ckpt_dir, path, lambda f: np.savez(f, **arrays))
    return path


def _sidecar_readable(path: str) -> bool:
    try:
        with open(path + ".treedef", "rb") as f:
            pickle.load(f)
        return True
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step whose checkpoint is actually loadable.  Checkpoints
    missing a readable treedef sidecar (torn write, lost file) are skipped
    with a warning instead of poisoning the resume."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in sorted(os.listdir(ckpt_dir)):
        if not (f.startswith("step_") and f.endswith(".npz")):
            continue
        step = int(f[len("step_"):-len(".npz")])
        if _sidecar_readable(os.path.join(ckpt_dir, f)):
            steps.append(step)
        else:
            warnings.warn(
                f"skipping checkpoint {f}: missing/unreadable treedef "
                f"sidecar (torn write?)", stacklevel=2)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, shardings=None):
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    try:
        with open(path + ".treedef", "rb") as f:
            treedef = pickle.load(f)
    except Exception as e:
        raise FileNotFoundError(
            f"checkpoint {path} has no readable treedef sidecar ({e}); "
            f"resume via latest_step() to skip torn checkpoints") from e
    data = np.load(path)
    leaves = [data[str(i)] for i in range(len(data.files))]
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
