"""Mesh-axis helpers and sharding-spec builders for the launch path.

Everything here is a pure function of (mesh axis sizes, array shapes): no
device state is touched, so the same builders serve the 1-device host mesh,
the 256-chip single pod and the 512-chip multi-pod mesh, and they are unit
testable without any mesh at all.

Conventions
-----------
  * ``data`` (and the outer ``pod`` axis on multi-pod meshes) are the
    data-parallel axes: batch dims shard over them, parameters are
    replicated over them (unless FSDP specs say otherwise),
  * ``model`` is the tensor-parallel axis: `repro.models.params` resolves
    which parameter dim it shards; the activation rules here mirror that
    choice at the canonical Megatron constraint points (`repro.models.actx`),
  * a dim is only ever sharded when its size divides the axis (XLA would
    pad otherwise, which the dry-run memory accounting must not hide).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _is_spec(x) -> bool:
    return isinstance(x, P)


def axis_sizes(mesh) -> dict:
    """``{axis_name: size}`` for a mesh (the input `repro.models.params`
    spec resolution wants)."""
    return dict(mesh.shape)


def data_axes(mesh) -> tuple:
    """The data-parallel mesh axes, outermost first: ``("pod", "data")`` on
    multi-pod meshes, ``("data",)`` otherwise."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _data_size(mesh) -> int:
    sizes = axis_sizes(mesh)
    return math.prod(sizes[a] for a in data_axes(mesh))


def _model_ok(mesh, n: int) -> bool:
    m = axis_sizes(mesh).get("model", 1)
    return m > 1 and n >= m and n % m == 0


def named(mesh, spec_tree):
    """Map a tree of ``PartitionSpec`` to ``NamedSharding`` on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# batch / cache / optimizer-state specs
# ---------------------------------------------------------------------------

def batch_spec(mesh, global_batch: int) -> P:
    """Spec for a leading batch dim: sharded over the data axes when the
    global batch divides them, replicated otherwise (degenerate meshes)."""
    da = data_axes(mesh)
    if da and global_batch % _data_size(mesh) == 0:
        return P(da if len(da) > 1 else da[0])
    return P(None)


def _leading_batch_spec(mesh, leaf) -> P:
    b = leaf.shape[0] if leaf.ndim else 0
    head = tuple(batch_spec(mesh, b)) if leaf.ndim else ()
    return P(*(head + (None,) * (leaf.ndim - len(head))))


def batch_specs(cfg: ArchConfig, mesh, batch) -> dict:
    """Specs for a model-input batch dict (tokens/labels + frontend
    embeddings): dim 0 is the global batch, everything else replicated."""
    del cfg  # uniform rule: every input leads with the batch dim
    return jax.tree.map(lambda leaf: _leading_batch_spec(mesh, leaf), batch)


def cache_specs(cfg: ArchConfig, mesh, cache) -> dict:
    """Specs for a serve cache (`repro.models.transformer.init_cache`
    structure): ``pos`` replicated; kv caches (L, B, T, K, hd) shard batch
    over data and kv-heads over ``model`` when divisible; SSM states
    (L, B, ...) shard batch over data."""
    del cfg
    da = data_axes(mesh)
    dsize = _data_size(mesh)

    def kv_spec(leaf) -> P:
        _, b, _, k, _ = leaf.shape
        return P(None,
                 (da if len(da) > 1 else da[0]) if b % dsize == 0 else None,
                 None,
                 "model" if _model_ok(mesh, k) else None,
                 None)

    def state_spec(leaf) -> P:
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % dsize == 0:
            spec[1] = da if len(da) > 1 else da[0]
        return P(*spec)

    out: dict = {}
    for key, sub in cache.items():
        if key == "pos":
            out[key] = P()
        elif key in ("kv", "attn_kv"):
            out[key] = jax.tree.map(kv_spec, sub)
        else:  # "state" (and any future per-layer recurrent state)
            out[key] = jax.tree.map(state_spec, sub)
    return out


def paged_cache_specs(cfg: ArchConfig, mesh, pool) -> P:
    """Spec for one paged KV pool (`repro.serve.paged_cache.init_page_pool`
    leaf, (L, P+1, page_size, K, hd)): kv-heads shard over ``model`` when
    divisible, everything else replicated.  The page dim is deliberately NOT
    sharded — the pool is one global resource indexed by per-request page
    tables, and sharding pages over data would turn every table gather into
    an all-to-all; replicating pages keeps gathers local (the serving
    analogue of the dense cache's replicated T dim)."""
    del cfg
    _, _, _, k, _ = pool.shape
    return P(None, None, None, "model" if _model_ok(mesh, k) else None, None)


# sync/async-state entries that are genuinely per-worker (one EF/residual
# accumulator per data shard) vs replicated scalars — see
# `dist.train.init_dist_sync_state` / `dist.async_engine.init_async_state`
# for the layouts.  RING keys additionally carry a delay-ring dim of size
# ``tau_max + 1`` between the worker dim and the param dims.
PER_WORKER_STATE_KEYS = ("err", "residual")
PER_WORKER_RING_KEYS = ("buf",)


def sync_state_specs(sync_state, pspecs, mesh) -> dict:
    """Specs for the distributed sync/async-state layouts
    (`dist.train.init_dist_sync_state`, `dist.async_engine.init_async_state`):
    per-worker entries shard their leading worker dim over the data axes
    (each shard holds only its own accumulator) and keep the param specs'
    ``model`` sharding on the trailing dims; ring entries replicate the ring
    dim between the two; everything else (step counters, tau schedule
    tables) replicates."""
    da = data_axes(mesh)
    head = da if len(da) > 1 else da[0]
    out = {}
    for key, val in sync_state.items():
        if key in PER_WORKER_RING_KEYS:
            out[key] = jax.tree.map(
                lambda spec: P(head, None, *tuple(spec)), pspecs,
                is_leaf=_is_spec)
        elif key in PER_WORKER_STATE_KEYS:
            out[key] = jax.tree.map(
                lambda spec: P(head, *tuple(spec)), pspecs, is_leaf=_is_spec)
        else:
            out[key] = jax.tree.map(lambda _: P(), val)
    return out


def replicated_specs(tree):
    """``P()`` for every leaf — the in-``shard_map`` spec of a replicated
    tree (params, optimizer state, scalar metrics)."""
    return jax.tree.map(lambda _: P(), tree)


def batch_shard_specs(tree, head):
    """Leading-dim-over-``head`` specs for a batch tree inside
    ``shard_map`` (shared by `dist.train` and `dist.async_engine` so the
    batch-sharding rule cannot drift between the two step builders)."""
    return jax.tree.map(
        lambda a: P(head, *((None,) * (a.ndim - 1))), tree)


def shard_state_specs(state, head) -> dict:
    """In-``shard_map`` specs for a per-worker state dict: entries named in
    the per-worker/ring key lists shard their leading worker dim over
    ``head`` (the manual data axes), the rest replicate.  Built per-leaf
    from ndim, so one builder serves every strategy/engine state layout
    (used by `dist.train` and `dist.async_engine`)."""
    worker_keys = PER_WORKER_STATE_KEYS + PER_WORKER_RING_KEYS
    return {key: (batch_shard_specs(val, head) if key in worker_keys
                  else replicated_specs(val))
            for key, val in state.items()}


def opt_state_specs(opt_state, pspecs):
    """Specs for an optimizer-state tree: entries that mirror the param tree
    (momentum ``mu``, Adam ``m``/``v``) inherit the param specs; scalars and
    anything else are replicated."""
    ptree = jax.tree.structure(pspecs, is_leaf=_is_spec)
    out = {}
    for key, val in opt_state.items():
        if jax.tree.structure(val) == ptree:
            out[key] = pspecs
        else:
            out[key] = jax.tree.map(lambda _: P(), val)
    return out


# ---------------------------------------------------------------------------
# activation rules (the `repro.models.actx` constraint points)
# ---------------------------------------------------------------------------

def make_act_rules(cfg: ArchConfig, mesh, *, batch_size: int, seq_len: int,
                   sequence_parallel: bool = False,
                   batch_axes: bool = True) -> dict:
    """kind -> ``NamedSharding`` rules for `repro.models.actx.constrain`.

    ``batch_axes=False`` drops the data axes from every rule — required when
    the forward runs *inside* a ``shard_map`` over the data axes (the batch
    dim is already local there and manual axes may not appear in
    ``with_sharding_constraint`` specs).
    """
    da = data_axes(mesh)
    dsize = _data_size(mesh)
    batch = (da if len(da) > 1 else da[0]) \
        if (batch_axes and da and batch_size % dsize == 0) else None

    def model_if(n: int):
        return "model" if _model_ok(mesh, n) else None

    heads = cfg.n_heads or 1
    seq = "model" if (sequence_parallel and _model_ok(mesh, seq_len)) else None
    rules = {
        # (B, S, d): sequence parallelism shards S over model between blocks
        "residual": P(batch, seq, None),
        # (B, S, ff)
        "ffn_hidden": P(batch, None, model_if(cfg.d_ff)),
        # (B, S, H, hd) / (B, S, K, hd)
        "attn_q": P(batch, None, model_if(heads), None),
        "attn_kv": P(batch, None, model_if(cfg.n_kv_heads or 1), None),
        # (B, S, V)
        "logits": P(batch, None, model_if(cfg.vocab_size)),
    }
    if cfg.is_moe:
        e = model_if(cfg.n_experts)
        # (E, G, C, d) / (E, G, C, ff): expert parallelism over model; the
        # token-group dim follows the batch when it is globally sharded.
        rules["moe_expert"] = P(e, batch, None, None)
        rules["moe_hidden"] = P(e, batch, None, None)
    return {k: NamedSharding(mesh, s) for k, s in rules.items()}
