"""Emulated-asynchrony trainer: bounded-staleness SGD on the real models.

The simulator (`repro.core.sim_engine`) exercises the paper's asynchronous
relaxations on the Quadratic testbed only; this module runs the same
bounded-delay delivery semantics against the *real* architectures, at the
same hot-path speed as the synchronous trainer (everything stays inside one
jitted program — asynchrony is emulated with device-resident state, never
with host-side threads).

Semantics (the bounded-delay model of §B.4 / "The Convergence of SGD in
Asynchronous Shared Memory", arXiv:1803.08841):

  * every step, every worker (data shard) computes a gradient at the
    *current* parameters and broadcasts it with a per-(step, worker) delay
    ``tau(t, w)`` drawn from an oblivious-adversary schedule
    (`repro.core.delivery.make_tau_schedule`), ``0 <= tau <= tau_max``;
  * the shared model applies, at step ``t``, exactly the messages whose
    delivery lands at ``t`` — a gradient produced at step ``s`` and applied
    at step ``t = s + tau`` *is* a stale gradient: it was computed at the
    ``tau``-steps-old iterate, which is what makes the emulation faithful
    without keeping parameter history;
  * gradients can be sparsified before "transmission" (top-k / one-bit with
    or without error feedback) — the combination the paper's headline
    empirical claim is about (EF may not help *asynchronous* sparsified
    SGD; see ``benchmarks/bench_async_ef.py``);
  * crashed workers (schedule entries of :data:`repro.core.delivery.DROPPED`)
    deliver nothing — their gradient mass is lost, like the simulator's
    crash model without substitution.

Delivery is realized one of two ways, selected by ``AsyncConfig.overlap``:

**Fused / overlapped path** (``overlap=True`` with a compressor — the
default): each worker's compact wire payload (top-k ``(vals, idx)`` or
one-bit ``(sign bitmap, means)`` from
`repro.core.scheduler.ef_compress_leaf_compact`) is all-gathered over the
data axes, and every gathered message is routed exactly once by
`delivery.delivery_plan`.  The step splits into two halves:

  * *consume-delivery half* — messages due now from EARLIER steps were
    decompressed into the dense *delivery-indexed* accumulator ring
    (``acc``, slot ``(s + tau) % capacity``) back when they arrived, so
    delivery is a take of slot ``t % capacity`` — a read of carried
    state, issued before the forward/backward and overlapped with it;
  * *launch-reduce half* — the fresh payload's all-gather is issued as
    soon as the backward finishes, and the WHOLE gathered panel is
    deposited by one fused masked decompress-scatter
    (`repro.kernels.cr_reduce` deposit ops: every live message lands in
    its slot, weights folding the aliveness mask).  ``tau == 0``
    self-deliveries land in the freshly-zeroed slot ``t % capacity`` and
    are taken right back, so delivery costs exactly one panel scatter
    per step regardless of ``tau_max``, and the collective's latency
    hides behind the optimizer and the NEXT step's forward/backward.

Compressed payloads therefore never round-trip through a dense ``pmean``:
the wire is the compact all-gather (the jaxpr audit's
``bytes_on_wire_async_tau*`` rows now sit ~8x below dense sync at
ratio 1/8, pinned by the golden inventory).

**Densified path** (``compressor="none"``, or ``overlap=False`` as the
escape hatch / trajectory reference): per-worker fixed-capacity delay
rings of dense f32 payloads (capacity ``tau_max + 1``), deposit at
``(t + tau) % cap``, take at ``t % cap``, one full-width ``pmean`` of the
taken slot.  The take is double-buffered — messages from earlier steps
are consumed before the fresh deposit, the ``tau == 0`` remainder after —
which is bitwise the single-take slot content (the dense wire cannot be
split into two collectives without doubling its bytes, so the dense path
keeps exactly the synchronous all-reduce volume).

Both paths deliver the same per-step mass, so their trajectories match
step for step (``tests/test_dist_parity.py``); with ``tau_max = 0`` every
message is delivered in the step it was produced and the engine reduces
exactly to synchronous data-parallel SGD — the parity tests pin it
against :func:`repro.dist.train.make_train_step` bitwise.

Like :func:`repro.dist.train.make_elastic_train_step`, the step body runs
inside a ``shard_map`` manual over the data axes with the ``model`` axis
left to GSPMD, so tensor parallelism is untouched.  (Caveat shared with
the compressed sync strategies: jax-0.4.x's SPMD partitioner rejects
``all_gather`` under partial-auto shard_map on tensor-parallel meshes, so
the fused path needs ``model == 1`` until the ROADMAP toolchain bump;
``overlap=False`` keeps compressed async available on those meshes.)
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import delivery as DLV
from repro.core.scheduler import (ef_compress_leaf, ef_compress_leaf_compact,
                                  leaf_rows_geometry, _from_rows)
from repro.dist.sharding import (batch_shard_specs, replicated_specs,
                                 shard_state_specs)
from repro.dist.train import (add_worker_dim, guarded_update, mean_grads,
                              squeeze_worker_dim, tree_all_finite)
from repro.jax_compat import shard_map
from repro.kernels.cr_reduce import ops as CR
from repro.models import transformer as TF
from repro.models import scan_utils as SU


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the emulated-asynchrony engine.

    ``horizon`` is the length of the pre-drawn tau schedule table; steps
    beyond it wrap around (set it >= the planned step count for faithful
    crash schedules).

    ``overlap`` selects the fused compress-then-reduce delivery (compact
    payload rings + `kernels.cr_reduce`; see the module docstring).  It
    only changes the program when a compressor is configured — dense
    delivery is a single ``pmean`` either way — and never changes the
    trajectory, only how/when the reduction runs.
    """

    tau_max: int = 0              # staleness bound (0 == synchronous)
    schedule: str = "uniform"     # repro.core.delivery.TAU_SCHEDULES
    axis_names: tuple = ("data",)
    compressor: str = "none"      # none | topk | onebit
    error_feedback: bool = True   # EF residuals (only with a compressor)
    topk_ratio: float = 1.0 / 64.0
    horizon: int = 1024           # tau schedule table length
    seed: int = 0                 # schedule RNG (oblivious adversary)
    track_gap: bool = True        # stale_gap2 metric costs a 2nd pmean
    crash_subst: bool = False     # renormalize dead-worker mass (see below)
    skip_nonfinite: bool = False  # drop NaN/Inf gradients + skip the step
    overlap: bool = True          # fused compress-then-reduce delivery
    kernel_impl: str = "auto"     # cr_reduce dispatch: auto | kernel | ref

    @property
    def capacity(self) -> int:
        """Delay-ring capacity: a message delayed by ``tau <= tau_max``
        is always consumed (densified rings) or still resident (payload
        rings) when its delivery step arrives."""
        return self.tau_max + 1

    @property
    def has_err(self) -> bool:
        return self.compressor != "none" and self.error_feedback

    @property
    def fused(self) -> bool:
        """The overlapped compact-payload delivery path is active."""
        return self.overlap and self.compressor != "none"


def _acc_rings_like(acfg: AsyncConfig, params_like, pspecs):
    """Zeroed (cap, M, R) delivery-indexed accumulator rings, per leaf."""
    cap = acfg.capacity
    flat_p, treedef = jax.tree.flatten(params_like)
    flat_s = treedef.flatten_up_to(pspecs)
    rings = [jnp.zeros((cap,) + leaf_rows_geometry(jnp.shape(a), sp)[:2],
                       jnp.float32) for a, sp in zip(flat_p, flat_s)]
    return jax.tree.unflatten(treedef, rings)


def init_async_state(acfg: AsyncConfig, mesh, params_like,
                     pspecs=None) -> dict:
    """Global layout of the state consumed by :func:`make_async_train_step`.

    Densified path: ``buf`` (the stale-gradient delay rings) and ``err``
    (EF residuals, only when compressing with error feedback) lead with a
    worker dim of size prod(data axes) — per-worker data, sharded over the
    data axes by `dist.sharding.sync_state_specs` exactly like
    ``init_dist_sync_state``'s accumulators.

    Fused path (``acfg.fused``; requires ``pspecs`` for the row-space
    payload geometry): ``acc`` holds the dense delivery-indexed
    accumulator rings of *gathered* messages — (cap, M, R) f32 per leaf,
    the same on every worker (each worker has received and decompressed
    every message), so the entries are replicated, not worker-sharded.
    In a real deployment this is each worker's local stale-gradient
    accumulator fed by received compressed messages; the emulation pays
    the replication to keep everything in one SPMD program.  ``err``
    stays per-worker.

    ``taus`` is the replicated (horizon, n_workers) delay table; ``step``
    the replicated step counter.
    """
    if acfg.schedule not in DLV.TAU_SCHEDULES:
        raise ValueError(f"unknown schedule {acfg.schedule!r}")
    sizes = dict(mesh.shape)
    n = math.prod(sizes[a] for a in acfg.axis_names)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "taus": jnp.asarray(DLV.make_tau_schedule(
            acfg.schedule, n, acfg.horizon, acfg.tau_max, acfg.seed)),
    }
    if acfg.fused:
        if pspecs is None:
            raise ValueError(
                "the fused (overlap) path sizes its delivery accumulator "
                "rings from the param PartitionSpecs — pass pspecs, or "
                "set overlap=False")
        state["acc"] = _acc_rings_like(acfg, params_like, pspecs)
    else:
        state["buf"] = jax.tree.map(
            lambda a: jnp.zeros((n, acfg.capacity, *a.shape), jnp.float32),
            params_like)
    if acfg.has_err:
        state["err"] = jax.tree.map(
            lambda a: jnp.zeros((n, *a.shape), jnp.float32), params_like)
    return state


def make_async_train_step(cfg: ArchConfig, opt, mesh, acfg: AsyncConfig,
                          pspecs, flags: TF.RunFlags = TF.DEFAULT_FLAGS,
                          grad_accum: int = 1):
    """Bounded-staleness step: ``(params, opt_state, async_state, batch) ->
    (params, opt_state, async_state, metrics)``.

    ``async_state`` must use the :func:`init_async_state` layout.  Metrics:
    ``loss`` (mean over workers), ``stale_gap2`` (||applied - fresh mean
    gradient||^2 — zero when ``tau_max == 0``, the engine's realized
    staleness gap), ``mean_tau`` (mean effective delay this step) and
    ``nonfinite`` (0/1: the step was skipped by the non-finite guard).
    The gap needs a second full-gradient pmean, so it is only computed when
    ``acfg.track_gap`` — turn it off to keep the hot path at exactly the
    configured wire volume (the metric then reports 0).

    Fault tolerance (both off by default — the hot path is byte-identical
    to the unguarded program):

      * ``acfg.crash_subst`` — the paper's crash-with-substitution
        semantics as mass *renormalization*: the mean divides by all ``n``
        workers even when crashed/delayed workers delivered nothing, so
        dead mass shrinks the effective step and a fully-crashed step still
        "applies" a zero gradient.  With the flag on, the applied mean is
        rescaled by ``n / delivered(t)`` (computable from the replicated
        tau table alone — the adversary is oblivious), so surviving
        workers' gradients carry full weight and training continues at the
        intended step size instead of stalling; ``delivered(t) == 0`` steps
        apply nothing.
      * ``acfg.skip_nonfinite`` — a worker whose local gradient has NaN/Inf
        leaves transmits *zeros* (its mass is dropped for that step, like a
        one-step crash — EF residuals keep draining but never absorb the
        poison), and the optimizer update is additionally guarded by
        `repro.dist.train.guarded_update` so a poisoned mean never reaches
        the params.
    """
    from jax.sharding import PartitionSpec as P

    manual = tuple(acfg.axis_names)
    sizes = dict(mesh.shape)
    auto = frozenset(a for a in mesh.axis_names if a not in manual
                     and sizes[a] > 1)
    head = manual if len(manual) > 1 else manual[0]
    cap = acfg.capacity

    flat_specs = None
    geoms = None

    def _leaf_specs(grads):
        nonlocal flat_specs
        flat_g, treedef = jax.tree.flatten(grads)
        if flat_specs is None:
            flat_specs = treedef.flatten_up_to(pspecs)
        return flat_g, treedef

    def _compress_dense(grads, err):
        flat_g, treedef = _leaf_specs(grads)
        flat_e = treedef.flatten_up_to(err)
        outs = [ef_compress_leaf(g, e, sp, acfg.compressor, acfg.topk_ratio)
                for g, e, sp in zip(flat_g, flat_e, flat_specs)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef, [o[1] for o in outs]))

    def pmean(tree):
        return jax.tree.map(
            lambda a: jax.lax.pmean(a.astype(jnp.float32), axis_name=manual),
            tree)

    def _gather(x):
        """Wire: all-gather one compact payload array over the data axes
        -> (n, ...) in worker order (matches the tau-table columns)."""
        g = jax.lax.all_gather(x, axis_name=manual, tiled=False)
        return g.reshape(-1, *x.shape)

    def _crash_subst_scale(tab, step):
        # delivered(t): how many messages land this step, read off the
        # replicated tau table (a message from step t-d with tau == d
        # arrives now).  Static unroll over the d <= tau_max window.
        horizon = tab.shape[0]
        cnt = jnp.zeros((), jnp.float32)
        for d in range(cap):
            src = step - d
            cnt += jnp.sum(((tab[src % horizon] == d) & (src >= 0))
                           .astype(jnp.float32))
        n_total = jnp.float32(tab.shape[1])
        return jnp.where(cnt > 0, n_total / cnt, 0.0)

    def _deposit(acc, panel, w_live, slots):
        """Fused masked decompress-deposit of the whole gathered panel:
        every live message is decompressed ONCE, straight into its
        delivery-indexed accumulator slot, by a single scatter
        (`kernels.cr_reduce` deposit ops — a zero weight makes a DROPPED
        message a no-op).  Never a collective."""
        if acfg.compressor == "topk":
            return CR.topk_deposit(acc, panel["vals"], panel["idx"],
                                   slots, w_live, impl=acfg.kernel_impl)
        return CR.onebit_deposit(acc, panel["pos"], panel["means"],
                                 slots, w_live, impl=acfg.kernel_impl)

    def local_step(params, opt_state, state, batch):
        nonlocal geoms
        local = squeeze_worker_dim(state)
        step = local["step"]
        tab = local["taus"]
        n_total = jnp.float32(tab.shape[1])

        if acfg.fused:
            # ---- consume-delivery half (state-only): every message due
            # now from EARLIER steps was decompressed into the
            # delivery-indexed accumulator when it arrived, so delivery
            # is a take of slot t % cap — issued before the
            # forward/backward, it overlaps the compute; no collective,
            # and each message was decompressed exactly once.
            w_live, slots = DLV.delivery_plan(tab, step, cap)
            flat_p, treedef = _leaf_specs(params)
            if geoms is None:
                geoms = [leaf_rows_geometry(p.shape, sp)
                         for p, sp in zip(flat_p, flat_specs)]
            prior_rows, accs = [], []
            for acc in treedef.flatten_up_to(local["acc"]):
                prior_rows.append(acc[step % cap])
                accs.append(acc.at[step % cap].set(0.0))
        else:
            # densified rings, double-buffered take: consume earlier
            # steps' deliveries before the fresh deposit lands
            prior, buf = DLV.tree_ring_take(local["buf"], step % cap)

        # ---- compute half -------------------------------------------------
        # jax 0.4.x partial-auto shard_map: unroll model scans (scan_utils)
        with SU.unrolled(bool(auto)):
            loss, _parts, grads = mean_grads(cfg, flags, params, batch,
                                             grad_accum)

        # this worker's delay for the gradient it just produced
        widx = jnp.int32(0)
        for a in manual:
            widx = widx * sizes[a] + jax.lax.axis_index(a)
        tau = tab[step % tab.shape[0], widx]
        alive = (tau >= 0).astype(jnp.float32)     # DROPPED == crashed
        d_eff = jnp.clip(tau, 0, acfg.tau_max)

        # poisoned local gradient -> transmit nothing (a one-step crash);
        # zeroing BEFORE compression keeps the EF residual finite forever
        if acfg.skip_nonfinite:
            g_finite = tree_all_finite(grads)
            grads = jax.tree.map(
                lambda g: jnp.where(g_finite, g, jnp.zeros_like(g)), grads)
            local_bad = 1.0 - g_finite.astype(jnp.float32)
        else:
            local_bad = jnp.zeros(())

        if acfg.fused:
            flat_g, treedef = _leaf_specs(grads)
            err_tree = local["err"] if acfg.has_err else jax.tree.map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads)
            flat_e = treedef.flatten_up_to(err_tree)

            new_accs, new_errs, delivered = [], [], []
            for g, e, sp, geom, acc, prior in zip(
                    flat_g, flat_e, flat_specs, geoms, accs, prior_rows):
                # local sparsification to the compact wire form
                payload, new_err = ef_compress_leaf_compact(
                    g, e, sp, acfg.compressor, acfg.topk_ratio,
                    impl=acfg.kernel_impl)
                new_errs.append(new_err)
                # ---- launch-reduce half: the wire is this all-gather of
                # the compact payload; ONE fused scatter deposits every
                # live message into its slot — tau == 0 self-deliveries
                # land in the just-zeroed slot t and are taken right back
                gathered = {key: _gather(v) for key, v in payload.items()}
                acc = _deposit(acc, gathered, w_live, slots)
                delivered.append(prior + acc[step % cap])
                new_accs.append(acc.at[step % cap].set(0.0))
            local["acc"] = jax.tree.unflatten(treedef, new_accs)
            if acfg.has_err:
                local["err"] = jax.tree.unflatten(treedef, new_errs)
            scale = 1.0 / n_total
            if acfg.crash_subst:
                scale = scale * _crash_subst_scale(tab, step)
            synced = jax.tree.unflatten(treedef, [
                _from_rows(rows * scale, geom[2], geom[3])
                for rows, geom in zip(delivered, geoms)])
        else:
            # local sparsification before "transmission"
            if acfg.compressor != "none":
                err = local["err"] if acfg.has_err else jax.tree.map(
                    lambda g: jnp.zeros_like(g, jnp.float32), grads)
                payload, new_err = _compress_dense(grads, err)
                if acfg.has_err:
                    local["err"] = new_err
            else:
                payload = jax.tree.map(lambda g: g.astype(jnp.float32),
                                       grads)

            # fresh payload lands tau steps ahead; the own-step (tau == 0)
            # remainder joins the pre-consumed deliveries — bitwise the
            # single-take slot content, one full-width pmean either way
            buf = DLV.tree_ring_deposit(
                buf, (step + d_eff) % cap,
                jax.tree.map(lambda v: v * alive, payload))
            own, buf = DLV.tree_ring_take(buf, step % cap)
            local["buf"] = buf
            stale = jax.tree.map(lambda a, b: a + b, prior, own)

            # the shared model applies the mean of everything delivered at t
            synced = pmean(stale)
            if acfg.crash_subst:
                s = _crash_subst_scale(tab, step)
                synced = jax.tree.map(lambda a: a * s, synced)

        if acfg.track_gap:
            fresh = pmean(grads)
            gap2 = sum(jnp.sum(jnp.square(a - b)) for a, b in
                       zip(jax.tree.leaves(synced), jax.tree.leaves(fresh)))
        else:
            gap2 = jnp.zeros(())

        params, opt_state, _skipped = guarded_update(
            opt, synced, opt_state, params,
            skip_nonfinite=acfg.skip_nonfinite)
        local["step"] = step + 1
        metrics = {
            "loss": jax.lax.pmean(loss, axis_name=manual),
            "stale_gap2": gap2,
            "mean_tau": jax.lax.pmean(d_eff.astype(jnp.float32),
                                      axis_name=manual),
            # fraction of workers whose local gradient was poisoned this
            # step (the launcher's skipped-step counter); the delivered
            # mean itself is re-guarded above
            "nonfinite": jax.lax.pmean(local_bad, axis_name=manual),
        }
        return params, opt_state, add_worker_dim(local), metrics

    def step(params, opt_state, state, batch):
        in_specs = (replicated_specs(params), replicated_specs(opt_state),
                    shard_state_specs(state, head),
                    batch_shard_specs(batch, head))
        out_specs = (replicated_specs(params), replicated_specs(opt_state),
                     shard_state_specs(state, head),
                     {"loss": P(), "stale_gap2": P(), "mean_tau": P(),
                      "nonfinite": P()})
        fn = shard_map(local_step, mesh, in_specs, out_specs,
                       check=False, auto=auto)
        return fn(params, opt_state, state, batch)

    return step
