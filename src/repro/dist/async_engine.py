"""Emulated-asynchrony trainer: bounded-staleness SGD on the real models.

The simulator (`repro.core.sim_engine`) exercises the paper's asynchronous
relaxations on the Quadratic testbed only; this module runs the same
bounded-delay delivery semantics against the *real* architectures, at the
same hot-path speed as the synchronous trainer (everything stays inside one
jitted program — asynchrony is emulated with device-resident state, never
with host-side threads).

Semantics (the bounded-delay model of §B.4 / "The Convergence of SGD in
Asynchronous Shared Memory", arXiv:1803.08841):

  * every step, every worker (data shard) computes a gradient at the
    *current* parameters and broadcasts it with a per-(step, worker) delay
    ``tau(t, w)`` drawn from an oblivious-adversary schedule
    (`repro.core.delivery.make_tau_schedule`), ``0 <= tau <= tau_max``;
  * the shared model applies, at step ``t``, exactly the messages whose
    delivery lands at ``t`` — a gradient produced at step ``s`` and applied
    at step ``t = s + tau`` *is* a stale gradient: it was computed at the
    ``tau``-steps-old iterate, which is what makes the emulation faithful
    without keeping parameter history;
  * delivery is realized with per-worker fixed-capacity delay rings
    (`repro.core.delivery`, capacity ``tau_max + 1``) kept in the training
    state with a leading worker dim sharded over the data axes — the same
    truthful per-worker layout as ``init_dist_sync_state``'s EF residuals;
  * gradients can be sparsified before "transmission" (top-k / one-bit via
    `repro.core.scheduler.ef_compress_leaf`), with or without error
    feedback — the combination the paper's headline empirical claim is
    about (EF may not help *asynchronous* sparsified SGD; see
    ``benchmarks/bench_async_ef.py``);
  * crashed workers (schedule entries of :data:`repro.core.delivery.DROPPED`)
    deposit nothing — their gradient mass is lost, like the simulator's
    crash model without substitution.

With ``tau_max = 0`` every message is delivered in the step it was produced
and the engine reduces exactly to synchronous data-parallel SGD — the
parity tests pin it against :func:`repro.dist.train.make_train_step`.

Like :func:`repro.dist.train.make_elastic_train_step`, the step body runs
inside a ``shard_map`` manual over the data axes with the ``model`` axis
left to GSPMD, so tensor parallelism is untouched.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import delivery as DLV
from repro.core.scheduler import ef_compress_leaf
from repro.dist.sharding import (batch_shard_specs, replicated_specs,
                                 shard_state_specs)
from repro.dist.train import (add_worker_dim, guarded_update, mean_grads,
                              squeeze_worker_dim, tree_all_finite)
from repro.jax_compat import shard_map
from repro.models import transformer as TF
from repro.models import scan_utils as SU


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the emulated-asynchrony engine.

    ``horizon`` is the length of the pre-drawn tau schedule table; steps
    beyond it wrap around (set it >= the planned step count for faithful
    crash schedules).
    """

    tau_max: int = 0              # staleness bound (0 == synchronous)
    schedule: str = "uniform"     # repro.core.delivery.TAU_SCHEDULES
    axis_names: tuple = ("data",)
    compressor: str = "none"      # none | topk | onebit
    error_feedback: bool = True   # EF residuals (only with a compressor)
    topk_ratio: float = 1.0 / 64.0
    horizon: int = 1024           # tau schedule table length
    seed: int = 0                 # schedule RNG (oblivious adversary)
    track_gap: bool = True        # stale_gap2 metric costs a 2nd pmean
    crash_subst: bool = False     # renormalize dead-worker mass (see below)
    skip_nonfinite: bool = False  # drop NaN/Inf gradients + skip the step

    @property
    def capacity(self) -> int:
        """Delay-ring capacity: a message delayed by ``tau <= tau_max``
        deposited at slot ``(t + tau) % capacity`` is always taken before
        the slot is reused."""
        return self.tau_max + 1

    @property
    def has_err(self) -> bool:
        return self.compressor != "none" and self.error_feedback


def init_async_state(acfg: AsyncConfig, mesh, params_like) -> dict:
    """Global layout of the state consumed by :func:`make_async_train_step`.

    ``buf`` (the stale-gradient delay rings) and ``err`` (EF residuals,
    only when compressing with error feedback) lead with a worker dim of
    size prod(data axes) — per-worker data, sharded over the data axes by
    `dist.sharding.sync_state_specs` exactly like ``init_dist_sync_state``'s
    accumulators.  ``taus`` is the replicated (horizon, n_workers) delay
    table; ``step`` the replicated step counter.
    """
    if acfg.schedule not in DLV.TAU_SCHEDULES:
        raise ValueError(f"unknown schedule {acfg.schedule!r}")
    sizes = dict(mesh.shape)
    n = math.prod(sizes[a] for a in acfg.axis_names)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "taus": jnp.asarray(DLV.make_tau_schedule(
            acfg.schedule, n, acfg.horizon, acfg.tau_max, acfg.seed)),
        "buf": jax.tree.map(
            lambda a: jnp.zeros((n, acfg.capacity, *a.shape), jnp.float32),
            params_like),
    }
    if acfg.has_err:
        state["err"] = jax.tree.map(
            lambda a: jnp.zeros((n, *a.shape), jnp.float32), params_like)
    return state


def make_async_train_step(cfg: ArchConfig, opt, mesh, acfg: AsyncConfig,
                          pspecs, flags: TF.RunFlags = TF.DEFAULT_FLAGS,
                          grad_accum: int = 1):
    """Bounded-staleness step: ``(params, opt_state, async_state, batch) ->
    (params, opt_state, async_state, metrics)``.

    ``async_state`` must use the :func:`init_async_state` layout.  Metrics:
    ``loss`` (mean over workers), ``stale_gap2`` (||applied - fresh mean
    gradient||^2 — zero when ``tau_max == 0``, the engine's realized
    staleness gap), ``mean_tau`` (mean effective delay this step) and
    ``nonfinite`` (0/1: the step was skipped by the non-finite guard).
    The gap needs a second full-gradient pmean, so it is only computed when
    ``acfg.track_gap`` — turn it off to keep the hot path at exactly the
    synchronous all-reduce volume (the metric then reports 0).

    Fault tolerance (both off by default — the hot path is byte-identical
    to the unguarded program):

      * ``acfg.crash_subst`` — the paper's crash-with-substitution
        semantics as mass *renormalization*: ``pmean`` divides by all ``n``
        workers even when crashed/delayed workers delivered nothing, so
        dead mass shrinks the effective step and a fully-crashed step still
        "applies" a zero gradient.  With the flag on, the applied mean is
        rescaled by ``n / delivered(t)`` (computable from the replicated
        tau table alone — the adversary is oblivious), so surviving
        workers' gradients carry full weight and training continues at the
        intended step size instead of stalling; ``delivered(t) == 0`` steps
        apply nothing.
      * ``acfg.skip_nonfinite`` — a worker whose local gradient has NaN/Inf
        leaves transmits *zeros* (its mass is dropped for that step, like a
        one-step crash — EF residuals keep draining but never absorb the
        poison), and the optimizer update is additionally guarded by
        `repro.dist.train.guarded_update` so a poisoned mean never reaches
        the params.
    """
    from jax.sharding import PartitionSpec as P

    manual = tuple(acfg.axis_names)
    sizes = dict(mesh.shape)
    auto = frozenset(a for a in mesh.axis_names if a not in manual
                     and sizes[a] > 1)
    head = manual if len(manual) > 1 else manual[0]
    cap = acfg.capacity

    def _compress(grads, err):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        flat_s = treedef.flatten_up_to(pspecs)
        outs = [ef_compress_leaf(g, e, sp, acfg.compressor, acfg.topk_ratio)
                for g, e, sp in zip(flat_g, flat_e, flat_s)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef, [o[1] for o in outs]))

    def pmean(tree):
        return jax.tree.map(
            lambda a: jax.lax.pmean(a.astype(jnp.float32), axis_name=manual),
            tree)

    def local_step(params, opt_state, state, batch):
        # jax 0.4.x partial-auto shard_map: unroll model scans (scan_utils)
        with SU.unrolled(bool(auto)):
            loss, _parts, grads = mean_grads(cfg, flags, params, batch,
                                             grad_accum)
        local = squeeze_worker_dim(state)
        step = local["step"]

        # this worker's delay for the gradient it just produced
        widx = jnp.int32(0)
        for a in manual:
            widx = widx * sizes[a] + jax.lax.axis_index(a)
        tau = local["taus"][step % local["taus"].shape[0], widx]
        alive = (tau >= 0).astype(jnp.float32)     # DROPPED == crashed
        d_eff = jnp.clip(tau, 0, acfg.tau_max)

        # poisoned local gradient -> transmit nothing (a one-step crash);
        # zeroing BEFORE compression keeps the EF residual finite forever
        if acfg.skip_nonfinite:
            g_finite = tree_all_finite(grads)
            grads = jax.tree.map(
                lambda g: jnp.where(g_finite, g, jnp.zeros_like(g)), grads)
            local_bad = 1.0 - g_finite.astype(jnp.float32)
        else:
            local_bad = jnp.zeros(())

        # local sparsification before "transmission"
        if acfg.compressor != "none":
            err = local["err"] if acfg.has_err else jax.tree.map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads)
            payload, new_err = _compress(grads, err)
            if acfg.has_err:
                local["err"] = new_err
        else:
            payload = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        # bounded-delay delivery through this worker's rings: deposit the
        # fresh payload tau steps ahead, take what lands this step
        buf = DLV.tree_ring_deposit(
            local["buf"], (step + d_eff) % cap,
            jax.tree.map(lambda v: v * alive, payload))
        stale, buf = DLV.tree_ring_take(buf, step % cap)
        local["buf"] = buf

        # the shared model applies the mean of everything delivered at t
        synced = pmean(stale)
        if acfg.crash_subst:
            # delivered(t): how many messages land this step, read off the
            # replicated tau table (a message from step t-d with tau == d
            # arrives now).  Static unroll over the d <= tau_max window.
            tab = local["taus"]
            horizon = tab.shape[0]
            cnt = jnp.zeros((), jnp.float32)
            for d in range(cap):
                src = step - d
                cnt += jnp.sum(((tab[src % horizon] == d) & (src >= 0))
                               .astype(jnp.float32))
            n_total = jnp.float32(tab.shape[1])
            scale = jnp.where(cnt > 0, n_total / cnt, 0.0)
            synced = jax.tree.map(lambda a: a * scale, synced)
        if acfg.track_gap:
            fresh = pmean(grads)
            gap2 = sum(jnp.sum(jnp.square(a - b)) for a, b in
                       zip(jax.tree.leaves(synced), jax.tree.leaves(fresh)))
        else:
            gap2 = jnp.zeros(())

        params, opt_state, _skipped = guarded_update(
            opt, synced, opt_state, params,
            skip_nonfinite=acfg.skip_nonfinite)
        local["step"] = step + 1
        metrics = {
            "loss": jax.lax.pmean(loss, axis_name=manual),
            "stale_gap2": gap2,
            "mean_tau": jax.lax.pmean(d_eff.astype(jnp.float32),
                                      axis_name=manual),
            # fraction of workers whose local gradient was poisoned this
            # step (the launcher's skipped-step counter); the delivered
            # mean itself is re-guarded above
            "nonfinite": jax.lax.pmean(local_bad, axis_name=manual),
        }
        return params, opt_state, add_worker_dim(local), metrics

    def step(params, opt_state, state, batch):
        in_specs = (replicated_specs(params), replicated_specs(opt_state),
                    shard_state_specs(state, head),
                    batch_shard_specs(batch, head))
        out_specs = (replicated_specs(params), replicated_specs(opt_state),
                     shard_state_specs(state, head),
                     {"loss": P(), "stale_gap2": P(), "mean_tau": P(),
                      "nonfinite": P()})
        fn = shard_map(local_step, mesh, in_specs, out_specs,
                       check=False, auto=auto)
        return fn(params, opt_state, state, batch)

    return step
