"""Distributed training/serving subsystem.

``repro.dist`` is the layer between the pure model code (`repro.models`),
the gradient-sync strategies (`repro.core.scheduler`) and the launchers
(`repro.launch`):

  * :mod:`repro.dist.sharding` — mesh-axis bookkeeping and the
    ``PartitionSpec``/``NamedSharding`` builders for params, optimizer state,
    batches, serve caches and activation constraint points,
  * :mod:`repro.dist.train` — the train/serve step builders: ``loss_fn``,
    ``make_train_step`` (plain GSPMD data parallel), ``make_elastic_train_step``
    (manual data-axis collectives via ``shard_map`` so the paper's relaxed
    sync strategies control exactly what crosses the wire), and
    ``make_prefill_step`` / ``make_decode_step`` for serving,
  * :mod:`repro.dist.async_engine` — ``make_async_train_step``: the
    bounded-staleness (emulated-asynchrony) trainer — per-worker stale
    gradient delay rings, crash/straggler tau schedules, top-k/one-bit
    sparsification with or without error feedback — on the same
    ``shard_map`` layout.

The module boundaries mirror the consumers: ``repro.launch.train`` /
``dryrun`` / ``serve`` import from here and run unmodified at every scale
from a 1-CPU smoke mesh to the 512-chip multi-pod dry-run mesh.
"""
from repro.dist import async_engine, sharding, train  # noqa: F401
