"""Train/serve step builders for the launch path.

Two training paths share one loss:

  * :func:`make_train_step` — plain data parallelism: one jitted program,
    GSPMD inserts the gradient all-reduce. This is the perfectly-consistent
    baseline (the paper's synchronous model) and the per-arch smoke path.
  * :func:`make_elastic_train_step` — the paper's relaxed-consistency path:
    the forward/backward and optimizer run *inside* a ``shard_map`` over the
    data-parallel mesh axes, so each shard holds its LOCAL gradient and
    `repro.core.scheduler.sync_gradients` decides what actually crosses the
    wire (dense pmean, top-k/1-bit error feedback, or the elastic
    norm/static-gated partial sync). Tensor parallelism over the ``model``
    axis stays automatic (GSPMD) via the shard-map ``auto`` axes, so the same
    step builder serves the 1-device host mesh and the 256/512-chip meshes.

Serving is two thin builders over `repro.models.transformer`'s prefill /
decode_step with greedy sampling: :func:`make_prefill_step` and
:func:`make_decode_step` (used by ``repro.launch.serve`` and the decode
dry-run shapes).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.scheduler import SyncConfig, init_sync_state, sync_gradients
from repro.dist.sharding import (PER_WORKER_RING_KEYS, PER_WORKER_STATE_KEYS,
                                 batch_shard_specs, replicated_specs,
                                 shard_state_specs)
from repro.jax_compat import shard_map
from repro.models import transformer as TF
from repro.models import scan_utils as SU
from repro.optim import apply_updates, global_norm


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ArchConfig, params, batch: dict,
            flags: TF.RunFlags = TF.DEFAULT_FLAGS):
    """Token-level cross entropy (+ weighted MoE router aux loss).

    Returns ``(loss, metrics)`` where metrics carries the unweighted parts;
    differentiable in ``params`` (use with ``value_and_grad(has_aux=True)``).

    An optional ``batch["loss_scale"]`` (shape (B,), normally all-ones)
    multiplies the loss — the fault-injection channel: a NaN/Inf scale
    poisons every gradient leaf, which the ``skip_nonfinite`` guard must
    then reject (`repro.faults`).  Shaped (B,) rather than scalar so the
    batch stays uniformly shardable over the data axes.
    """
    logits, aux = TF.forward(cfg, params, batch, flags)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    ce = jnp.mean(nll)
    loss = ce + cfg.router_aux_weight * aux
    if "loss_scale" in batch:
        loss = loss * jnp.mean(batch["loss_scale"].astype(jnp.float32))
    return loss, {"ce": ce, "aux_loss": aux}


def _value_and_grad(cfg, flags):
    return jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, flags), has_aux=True)


def _microbatch(batch, n: int):
    """(B, ...) -> (n, B//n, ...) for gradient accumulation."""
    return jax.tree.map(
        lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)


def mean_grads(cfg, flags, params, batch, grad_accum: int):
    """Loss + mean gradient, optionally accumulated over ``grad_accum``
    microbatches with a ``lax.scan`` (keeps the HLO one-microbatch sized).
    Shared by every train-step builder here and in `dist.async_engine`."""
    vg = _value_and_grad(cfg, flags)
    if grad_accum <= 1:
        (loss, parts), grads = vg(params, batch)
        return loss, parts, grads

    micro = _microbatch(batch, grad_accum)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    init = (jnp.zeros(()), {"ce": jnp.zeros(()), "aux_loss": jnp.zeros(())},
            zeros)

    def body(carry, mb):
        loss_acc, parts_acc, g_acc = carry
        (loss, parts), g = vg(params, mb)
        return (loss_acc + loss,
                jax.tree.map(lambda a, b: a + b, parts_acc, parts),
                jax.tree.map(lambda a, b: a + b, g_acc, g)), None

    (loss, parts, grads), _ = SU.scan(body, init, micro)
    inv = 1.0 / grad_accum
    return (loss * inv, jax.tree.map(lambda a: a * inv, parts),
            jax.tree.map(lambda g: g * inv, grads))


# ---------------------------------------------------------------------------
# non-finite gradient guard (fault tolerance)
# ---------------------------------------------------------------------------

def tree_all_finite(tree) -> jax.Array:
    """Scalar bool: every leaf of ``tree`` is finite everywhere."""
    checks = [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(tree)]
    out = checks[0]
    for c in checks[1:]:
        out = jnp.logical_and(out, c)
    return out


def guarded_update(opt, grads, opt_state, params, *, skip_nonfinite: bool):
    """Optimizer update with an optional skip-step guard: when
    ``skip_nonfinite`` and any gradient leaf is NaN/Inf, params and
    optimizer state pass through unchanged (the poisoned step is dropped,
    not applied).  Returns ``(params, opt_state, nonfinite)`` where
    ``nonfinite`` is the 0/1 skip indicator (a counter metric for the
    launcher).  With the guard off the program is exactly the unguarded
    update — no finiteness reduction is traced."""
    updates, new_opt = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)
    if not skip_nonfinite:
        return new_params, new_opt, jnp.zeros(())
    finite = tree_all_finite(grads)
    sel = lambda new, old: jnp.where(finite, new, old)  # noqa: E731
    return (jax.tree.map(sel, new_params, params),
            jax.tree.map(sel, new_opt, opt_state),
            1.0 - finite.astype(jnp.float32))


# ---------------------------------------------------------------------------
# training steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt, flags: TF.RunFlags = TF.DEFAULT_FLAGS,
                    grad_accum: int = 1, *, skip_nonfinite: bool = False):
    """Exact-sync step: ``(params, opt_state, batch) -> (params, opt_state,
    metrics)``. Pure single-program data parallelism — when the batch is
    sharded over the data axes, GSPMD inserts the dense gradient all-reduce
    (the BytePS-semantics baseline every relaxation is compared against).

    ``skip_nonfinite`` arms the :func:`guarded_update` skip-step guard and
    adds a ``nonfinite`` 0/1 metric; off (the default) the program is
    unchanged."""

    def step(params, opt_state, batch):
        loss, parts, grads = mean_grads(cfg, flags, params, batch, grad_accum)
        params, opt_state, nonfinite = guarded_update(
            opt, grads, opt_state, params, skip_nonfinite=skip_nonfinite)
        metrics = {"loss": loss, "grad_norm": global_norm(grads), **parts}
        if skip_nonfinite:
            metrics["nonfinite"] = nonfinite
        return params, opt_state, metrics

    return step


# strategy-state entries that hold one accumulator PER data shard (EF error,
# elastic residual, async delay rings) — everything else (step counters) is
# replicated; shared with `dist.sharding.sync_state_specs` so step layout
# and specs can't drift
_PER_WORKER_KEYS = PER_WORKER_STATE_KEYS + PER_WORKER_RING_KEYS


def squeeze_worker_dim(state: dict) -> dict:
    """Inside ``shard_map``: per-worker entries arrive as this shard's
    (1, ...) slice of the global worker-dim layout — drop the dim."""
    return {k: (jax.tree.map(lambda a: jnp.squeeze(a, 0), v)
                if k in _PER_WORKER_KEYS else v)
            for k, v in state.items()}


def add_worker_dim(state: dict) -> dict:
    """Inverse of :func:`squeeze_worker_dim` before leaving the shard_map."""
    return {k: (jax.tree.map(lambda a: a[None], v)
                if k in _PER_WORKER_KEYS else v)
            for k, v in state.items()}


def init_dist_sync_state(scfg: SyncConfig, mesh, params_like) -> dict:
    """Global layout of the strategy state consumed by
    :func:`make_elastic_train_step`.

    Error-feedback/residual accumulators are genuinely per-worker data
    (Alg 6 keeps one eps_i per worker), so those entries carry a leading
    worker dim of size prod(data axes) — globally the state IS p different
    residuals, and `dist.sharding.sync_state_specs` shards that dim over
    the data axes so each device stores only its own slice. Declaring them
    replicated instead would silently collapse all workers' residuals to
    device 0's copy on any host fetch or checkpoint.
    """
    base = init_sync_state(
        scfg, jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           params_like))
    sizes = dict(mesh.shape)
    n = math.prod(sizes[a] for a in scfg.axis_names)
    return {k: (jax.tree.map(lambda a: jnp.zeros((n, *a.shape), a.dtype), v)
                if k in _PER_WORKER_KEYS else v)
            for k, v in base.items()}


def make_elastic_train_step(cfg: ArchConfig, opt, mesh, scfg: SyncConfig,
                            pspecs, flags: TF.RunFlags = TF.DEFAULT_FLAGS,
                            static_phase: int = 0, grad_accum: int = 1):
    """Relaxed-sync step: ``(params, opt_state, sync_state, batch) ->
    (params, opt_state, sync_state, metrics)``.

    ``sync_state`` must use the :func:`init_dist_sync_state` layout:
    per-worker accumulators carry a leading worker dim sharded over the data
    axes (truthful sharding — each shard's EF residual is distinct data).

    The body runs inside a ``shard_map`` whose manual axes are
    ``scfg.axis_names`` (the data-parallel axes): each shard computes the
    gradient of ITS batch slice, then ``sync_gradients`` runs the configured
    strategy's collectives by hand — that is what makes partial/compressed
    synchronization expressible at all (GSPMD would always emit the dense
    all-reduce). Remaining mesh axes (``model``) are left to the compiler, so
    ``pspecs``-sharded parameters keep their tensor parallelism; ``pspecs``
    is also what the compressed strategies use to compress only along
    non-model dims.

    ``static_phase`` is the compile-time phase for the elastic static gate
    (each phase is its own program so skipped buckets emit no collective).
    """
    manual = tuple(scfg.axis_names)
    auto = frozenset(a for a in mesh.axis_names if a not in manual
                     and dict(mesh.shape)[a] > 1)

    head = manual if len(manual) > 1 else manual[0]

    def local_step(params, opt_state, sync_state, batch):
        # jax 0.4.x: a while loop inside a partial-auto shard_map hits a
        # fatal XLA SPMD-partitioner check, so unroll the model scans
        # whenever auto (tensor-parallel) axes are present (see scan_utils)
        with SU.unrolled(bool(auto)):
            loss, parts, grads = mean_grads(cfg, flags, params, batch,
                                            grad_accum)
        # per-worker state arrives as this shard's (1, ...) slice of the
        # global worker-dim layout (init_dist_sync_state)
        local = squeeze_worker_dim(sync_state)
        synced, local, smetrics = sync_gradients(
            scfg, grads, local, specs=pspecs, static_phase=static_phase)
        sync_state = add_worker_dim(local)
        updates, opt_state = opt.update(synced, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {
            "loss": jax.lax.pmean(loss, axis_name=manual),
            "gap2_over_alpha2": smetrics.get("gap2_over_alpha2",
                                             jnp.zeros(())),
        }
        return params, opt_state, sync_state, metrics

    def step(params, opt_state, sync_state, batch):
        # specs are built per-call from the actual arg trees, so one builder
        # serves every optimizer/strategy state layout
        in_specs = (replicated_specs(params), replicated_specs(opt_state),
                    shard_state_specs(sync_state, head),
                    batch_shard_specs(batch, head))
        out_specs = (replicated_specs(params), replicated_specs(opt_state),
                     shard_state_specs(sync_state, head),
                     {"loss": P(), "gap2_over_alpha2": P()})
        fn = shard_map(local_step, mesh, in_specs, out_specs,
                       check=False, auto=auto)
        return fn(params, opt_state, sync_state, batch)

    return step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def _greedy(logits) -> jax.Array:
    """(B, 1, V) last-position logits -> (B,) int32 greedy tokens."""
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(cfg: ArchConfig, max_len: int,
                      flags: TF.RunFlags = TF.DEFAULT_FLAGS, sample=None):
    """``(params, batch) -> (tokens (B,), cache)``: run the prompt, allocate
    a ``max_len`` cache, emit the first continuation token.

    ``sample`` is an optional `repro.serve.sampling.SampleConfig`; None or a
    greedy config keeps the exact legacy signature, a sampled config makes
    the step ``(params, batch, key) -> ...``."""

    if sample is not None and not sample.is_greedy:
        from repro.serve.sampling import sample_tokens

        def sampled_prefill_step(params, batch, key):
            logits, cache = TF.prefill(cfg, params, batch, max_len, flags)
            return sample_tokens(logits[:, -1, :], sample, key), cache

        return sampled_prefill_step

    def prefill_step(params, batch):
        logits, cache = TF.prefill(cfg, params, batch, max_len, flags)
        return _greedy(logits), cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, flags: TF.RunFlags = TF.DEFAULT_FLAGS,
                     sample=None):
    """``(params, cache, tokens (B, 1)) -> (tokens (B,), cache)``: one
    batched decode step at position ``cache['pos']`` (donate the cache — it
    is updated in place).

    ``sample`` as in :func:`make_prefill_step`: sampled configs add a
    trailing ``key`` argument, greedy/None keeps the legacy signature."""

    if sample is not None and not sample.is_greedy:
        from repro.serve.sampling import sample_tokens

        def sampled_decode_step(params, cache, tokens, key):
            logits, cache = TF.decode_step(cfg, params, cache, tokens, flags)
            return sample_tokens(logits[:, -1, :], sample, key), cache

        return sampled_decode_step

    def decode_step(params, cache, tokens):
        logits, cache = TF.decode_step(cfg, params, cache, tokens, flags)
        return _greedy(logits), cache

    return decode_step
