"""gemma3-27b — dense decoder, 5:1 local:global attention, 128k context.

[hf:google/gemma-3 family card] 62L, d_model=5376, 32 heads (GQA kv=16),
d_ff=21504, vocab=262144. Local layers use SWA(1024); every 6th layer is
global. qk_norm per gemma3.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,
    sliding_window=1024,
    global_every=6,       # 5 local : 1 global
    tie_embeddings=True,
    block_type=BLOCK_ATTN,
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt",
)
