"""grok-1-314b — 314B-parameter MoE decoder.

[hf:xai-org/grok-1] 64L, d_model=6144, 48 heads (GQA kv=8), d_ff=32768,
vocab=131072, MoE with 8 experts / top-2 routing.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    experts_per_token=2,
    block_type=BLOCK_ATTN,
    rope_theta=1e4,
    source="hf:xai-org/grok-1",
)
