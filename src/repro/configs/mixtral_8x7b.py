"""mixtral-8x7b — MoE decoder with sliding-window attention.

[arXiv:2401.04088] 32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=32000, MoE 8 experts / top-2, SWA window 4096.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    block_type=BLOCK_ATTN,
    rope_theta=1e6,
    source="arXiv:2401.04088",
)
