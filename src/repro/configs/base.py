"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke variants
are derived with ``cfg.reduced()``. Configs are registered by id in
``repro.configs.registry`` and selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

# Block kinds a layer stack can be made of.
BLOCK_ATTN = "attn"      # transformer block (attention + MLP/MoE)
BLOCK_MAMBA2 = "mamba2"  # Mamba2 SSD block
BLOCK_RWKV6 = "rwkv6"    # RWKV-6 (Finch) block

FRONTEND_NONE = "none"
FRONTEND_AUDIO = "audio"    # stub: precomputed EnCodec frame embeddings
FRONTEND_VISION = "vision"  # stub: precomputed ViT patch embeddings


@dataclass(frozen=True)
class ArchConfig:
    """Complete architecture description (backbone only for audio/vlm)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    n_heads: int = 0             # 0 => attention-free architecture
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False
    sliding_window: int = 0      # 0 => full causal attention
    global_every: int = 0        # gemma3: every Nth layer is global (rest SWA)
    rope_theta: float = 1e4
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_d_ff: int = 0            # expert hidden size (0 => d_ff)
    # --- SSM (mamba2 / rwkv6) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0   # invoke the single shared attn block every N layers
    # --- stack composition ---
    block_type: str = BLOCK_ATTN
    # --- modality frontend (stub per brief) ---
    frontend: str = FRONTEND_NONE
    n_prefix_embeds: int = 0     # vlm: number of prepended patch embeddings
    n_codebooks: int = 0         # audio: EnCodec codebooks (embeddings summed)
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    source: str = ""             # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def attention_free(self) -> bool:
        return self.block_type in (BLOCK_MAMBA2, BLOCK_RWKV6) and self.shared_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long_500k (SSM / hybrid / windowed attn)."""
        if self.block_type in (BLOCK_MAMBA2, BLOCK_RWKV6):
            return True
        return self.sliding_window > 0

    def layer_window_sizes(self) -> list[int]:
        """Per-layer attention window (0 = full/global) for BLOCK_ATTN stacks."""
        out = []
        for i in range(self.n_layers):
            if self.sliding_window and self.global_every:
                # gemma3 pattern: every `global_every`-th layer is global.
                out.append(0 if (i + 1) % self.global_every == 0 else self.sliding_window)
            elif self.sliding_window:
                out.append(self.sliding_window)
            else:
                out.append(0)
        return out

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        hd = self.resolved_head_dim
        for _ in range(self.n_layers):
            if self.block_type == BLOCK_ATTN:
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d  # qkvo
                if self.is_moe:
                    n += self.n_experts * 3 * d * self.expert_d_ff + d * self.n_experts
                else:
                    n += 3 * d * self.d_ff
                n += 2 * d  # norms
            elif self.block_type == BLOCK_MAMBA2:
                di = self.ssm_expand * d
                n += d * (2 * di + 2 * self.ssm_state) + di * d + 2 * d
            elif self.block_type == BLOCK_RWKV6:
                n += 6 * d * d + 3 * d * self.d_ff // 2 + 2 * d
        if self.shared_attn_every:
            n += 4 * d * d + 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        per_layer_expert = 3 * self.d_model * self.expert_d_ff
        inactive = self.n_layers * (self.n_experts - self.experts_per_token) * per_layer_expert
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests (brief: 2 layers,
        d_model<=512, <=4 experts)."""
        d = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else 0
        if self.block_type == BLOCK_RWKV6:
            ssm_state, ssm_heads = 16, d // 16  # rwkv requires h*n == d
        else:
            ssm_state = min(self.ssm_state, 16) if self.ssm_state else 0
            ssm_heads = min(self.ssm_heads, 4) if self.ssm_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            d_ff=min(self.d_ff, 4 * d),
            moe_d_ff=min(self.expert_d_ff, 2 * d) if self.is_moe else 0,
            vocab_size=min(self.vocab_size, 512),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d // n_heads if n_heads else 0,
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            ssm_state=ssm_state,
            ssm_heads=ssm_heads,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            global_every=self.global_every,
            shared_attn_every=self.shared_attn_every,
            n_prefix_embeds=min(self.n_prefix_embeds, 8) if self.n_prefix_embeds else 0,
        )


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
