"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.internvl2_2b import CONFIG as _internvl2
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.gemma3_27b import CONFIG as _gemma3

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _musicgen, _internvl2, _grok, _moonshot, _zamba2,
        _rwkv6, _nemo, _mixtral, _qwen3, _gemma3,
    ]
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return REGISTRY[name[: -len("-smoke")]].reduced()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
