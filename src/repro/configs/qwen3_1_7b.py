"""qwen3-1.7b — dense decoder with QK-norm.

[hf:Qwen/Qwen3-8B family card] 28L, d_model=2048, 16 heads (GQA kv=8),
head_dim=128, d_ff=6144, vocab=151936, qk_norm.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    block_type=BLOCK_ATTN,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)
