"""internvl2-2b — InternViT vision encoder + InternLM2 language model.

[arXiv:2404.16821] LM backbone: 24L, d_model=2048, 16 heads (GQA kv=8),
d_ff=8192, vocab=92553. The InternViT encoder + MLP projector are a stub per
the brief: ``input_specs`` provides 256 precomputed patch embeddings prepended
to the text token embeddings.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN, FRONTEND_VISION

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    block_type=BLOCK_ATTN,
    frontend=FRONTEND_VISION,
    n_prefix_embeds=256,
    rope_theta=1e6,
    source="arXiv:2404.16821",
)
