"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 81L, d_model=3584, 32 heads (GQA kv=32) in the shared
attention block, d_ff=14336, vocab=32000, ssm_state=64. The single shared
transformer block is applied every 6 Mamba2 layers (weights shared).
"""
from repro.configs.base import ArchConfig, BLOCK_MAMBA2

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=64,       # d_inner = 2*3584 = 7168; head_dim 112 -> 64 heads
    #                     (64 divides the 16-way model axis cleanly; 56 heads
    #                     of dim 128 would leave the SSD tensors unshardable
    #                     - see EXPERIMENTS.md SPerf zamba2/1)
    ssm_expand=2,
    conv_width=4,
    shared_attn_every=6,
    block_type=BLOCK_MAMBA2,
    source="arXiv:2411.15242",
)
