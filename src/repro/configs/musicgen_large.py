"""musicgen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48L, d_model=2048, 32 heads (GQA kv=32), d_ff=8192,
vocab=2048 (per-codebook). The EnCodec conv codec frontend is a stub per the
brief: ``input_specs`` provides precomputed frame embeddings (sum of the 4
codebook embeddings, delay-pattern applied upstream).
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN, FRONTEND_AUDIO

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_type=BLOCK_ATTN,
    frontend=FRONTEND_AUDIO,
    n_codebooks=4,
    rope_theta=1e4,
    source="arXiv:2306.05284",
)
