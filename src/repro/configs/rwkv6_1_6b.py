"""rwkv6-1.6b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 24L, d_model=2048, attn-free, d_ff=7168 (channel-mix),
vocab=65536. WKV6 state: 32 heads x 64x64 per layer.
"""
from repro.configs.base import ArchConfig, BLOCK_RWKV6

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    ssm_state=64,       # per-head k/v dim of the WKV state
    ssm_heads=32,       # d_model / 64
    block_type=BLOCK_RWKV6,
    source="arXiv:2404.05892",
)
