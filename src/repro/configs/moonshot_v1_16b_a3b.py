"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — fine-grained MoE.

[hf:moonshotai/Moonlight-16B-A3B] 48L, d_model=2048, 16 heads (GQA kv=16),
per-expert d_ff=1408, vocab=163840, MoE 64 experts / top-6.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_token=6,
    moe_d_ff=1408,
    block_type=BLOCK_ATTN,
    rope_theta=5e4,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
