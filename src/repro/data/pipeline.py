"""Deterministic synthetic data pipeline.

No datasets ship in this container, so the pipeline generates *learnable*
synthetic token streams: a fixed random Markov-chain over the vocabulary
(temperature-controlled), so the loss has real signal (a model that learns
the transition table beats the entropy floor) and convergence benchmarks are
meaningful. Batches are a pure function of (seed, step) — restart-safe and
shardable (each data shard derives its slice from its global batch offset,
so the global stream is independent of the mesh layout).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape


@dataclass
class SyntheticLMDataset:
    """Markov-chain token stream. ``batch(step)`` -> dict of arrays."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    order_states: int = 64  # markov states (vocab folded into states)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        s = self.order_states
        logits = rng.normal(size=(s, s)) * 2.0
        self._trans = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        # deterministic state->token expansion
        self._emit = rng.integers(0, self.vocab_size, size=(s, 4))

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, t, s = self.batch_size, self.seq_len, self.order_states
        states = np.zeros((b, t + 1), np.int64)
        states[:, 0] = rng.integers(0, s, size=b)
        u = rng.random((b, t))
        cdf = np.cumsum(self._trans, axis=-1)
        for i in range(t):
            states[:, i + 1] = np.argmax(cdf[states[:, i]] > u[:, i:i + 1],
                                         axis=-1)
        emit_choice = rng.integers(0, self._emit.shape[1], size=(b, t + 1))
        tokens = self._emit[states, emit_choice].astype(np.int32)
        return {
            "tokens": jnp.asarray(tokens[:, :-1]),
            "labels": jnp.asarray(tokens[:, 1:]),
        }


def synthetic_batch(cfg: ArchConfig, batch_size: int, seq_len: int,
                    seed: int = 0) -> dict:
    """One random batch with the frontend-stub extras an arch needs."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (batch_size, seq_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32),
        "labels": jax.random.randint(k2, (batch_size, seq_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32),
    }
    if cfg.frontend == "audio":
        batch["frame_embeds"] = 0.02 * jax.random.normal(
            k3, (batch_size, seq_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            k3, (batch_size, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return batch


def make_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Allocation-free ShapeDtypeStruct stand-ins for every model input of a
    workload (the dry-run's ``input_specs()``)."""
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        s = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.frontend == "audio":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.float32)
        if cfg.frontend == "vision":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
