"""Step engine: jitted paged-cache decode + per-request host bookkeeping.

The actor/step-engine split the ROADMAP prescribes: `StepEngine` owns the
device state (params or a staleness-bounded `ParamReplica`, the paged KV
pools, one compiled decode program) and exposes exactly three verbs —
``start`` (prefill + page allocation), ``step`` (one decode step for every
active slot), ``finish`` (free pages/slot).  Admission policy, queues and
completion tracking live in `repro.serve.scheduler`.

Parity by construction with the dense legacy loop
(`repro.dist.train.make_decode_step`):

  * the pre-attention math is literally the same code
    (`repro.models.layers.project_qkv`),
  * full attention gathers the whole page table, which with in-order pages
    reproduces the dense ``(R, T, K, hd)`` cache layout — same shapes, same
    masked positions, so the decode step is bitwise-identical per request
    when ``max_pages_per_seq * page_size`` equals the dense ``max_len``,
  * windowed layers gather only the ``ceil(window/ps) + 1`` live pages per
    request and run the `swa_attention` kernel (or the masked-chunk oracle)
    with per-request positions and page-base offsets — the hot path never
    reads a dead page.

One decode program serves every mix of requests: inactive slots write to the
pool's scratch page and their rows are positionally masked, so admission and
eviction never recompile.  Prefill compiles once per page-count bucket
(prompts pad to a page multiple).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, BLOCK_ATTN, FRONTEND_NONE
from repro.kernels.swa_attention import ops as SWA
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import scan_utils as SU
from repro.models import transformer as TF
from repro.serve import paged_cache as PC
from repro.serve.paged_cache import PagedCacheConfig, PageAllocator
from repro.serve.replica import ParamReplica
from repro.serve.sampling import SampleConfig, sample_tokens


def validate_paged_support(cfg: ArchConfig) -> int:
    """Paged serving supports uniform-window attention stacks; returns the
    (single) window size.  Grouped local:global (gemma3), SSM and frontend
    archs keep the dense legacy loop."""
    if cfg.block_type != BLOCK_ATTN:
        raise NotImplementedError(
            f"paged serving needs an attention stack, got {cfg.block_type}")
    if cfg.frontend != FRONTEND_NONE:
        raise NotImplementedError("paged serving: token frontends only")
    windows = set(cfg.layer_window_sizes())
    if len(windows) != 1:
        raise NotImplementedError(
            f"paged serving needs a uniform window, got {sorted(windows)}")
    return windows.pop()


# ---------------------------------------------------------------------------
# jitted step builders
# ---------------------------------------------------------------------------

def _attend_full(cfg, q, kp, vp, table, pos, positions, dt):
    """Full-table gather + masked-chunk attention (the parity path)."""
    r = q.shape[0]
    hd = cfg.resolved_head_dim
    nk = cfg.n_kv_heads
    keys = PC.gather_all(kp, table).astype(dt)
    vals = PC.gather_all(vp, table).astype(dt)
    t = keys.shape[1]
    k_pos = jnp.arange(t)[None]
    k_pos = jnp.where(k_pos <= pos[:, None], k_pos, -1)
    q5 = q.reshape(r, 1, nk, cfg.n_heads // nk, hd)
    out = L.masked_attn_chunk(q5, keys, vals, positions, k_pos, 0,
                              hd ** -0.5)
    return out.reshape(r, 1, cfg.n_heads, hd).astype(dt)


def _attend_window(cfg, pcfg, q, kp, vp, table, pos, positions, dt, *,
                   window: int, use_kernel: bool):
    """Windowed gather (live pages only) + kernel or masked-chunk oracle."""
    r = q.shape[0]
    hd = cfg.resolved_head_dim
    nk = cfg.n_kv_heads
    n_table = table.shape[1]
    start, n_win = PC.window_slots(pos, window, pcfg, n_table)
    keys, base = PC.gather_window(kp, table, start, n_win)
    vals, _ = PC.gather_window(vp, table, start, n_win)
    keys, vals = keys.astype(dt), vals.astype(dt)
    t = keys.shape[1]
    if use_kernel and t % 128 == 0:
        q4 = q[:, 0].reshape(r, nk, cfg.n_heads // nk, hd)
        out = SWA.decode_attention(q4, keys, vals, pos, base, window=window,
                                   use_kernel=True, interpret=True)
        return out.reshape(r, 1, cfg.n_heads, hd).astype(dt)
    k_pos = base[:, None] + jnp.arange(t)[None]
    k_pos = jnp.where(k_pos <= pos[:, None], k_pos, -1)
    q5 = q.reshape(r, 1, nk, cfg.n_heads // nk, hd)
    out = L.masked_attn_chunk(q5, keys, vals, positions, k_pos, window,
                              hd ** -0.5)
    return out.reshape(r, 1, cfg.n_heads, hd).astype(dt)


def make_paged_decode_step(cfg: ArchConfig, pcfg: PagedCacheConfig,
                           flags: TF.RunFlags = TF.DEFAULT_FLAGS, *,
                           window: int = 0,
                           sample: SampleConfig = SampleConfig(),
                           use_kernel: bool = False,
                           check_finite: bool = False):
    """``(params, k_pool, v_pool, tokens (R,), pos (R,), table, active,
    key) -> (tokens (R,), pos (R,), k_pool, v_pool)`` — one decode step for
    all R request slots (donate the pools).  Mirrors
    `repro.models.transformer.decode_step` layer for layer, with the dense
    cache update swapped for a page scatter/gather.  ``pos`` is advanced
    in-jit for active slots so the hot loop never re-uploads it.

    ``check_finite`` appends a per-slot ``finite`` (R,) bool output (all
    last-position logits finite) — the quarantine signal.  Off by default
    so the hot path's program stays byte-identical."""
    ps = pcfg.page_size
    r, n_table = pcfg.max_requests, pcfg.max_pages_per_seq

    def step(params, k_pool, v_pool, tokens, pos, table, active, key):
        x = jnp.take(params["embed"], tokens[:, None],
                     axis=0).astype(L.COMPUTE_DTYPE)          # (R, 1, d)
        positions = pos[:, None]
        cur_slot = jnp.minimum(pos // ps, n_table - 1)
        page_idx = jnp.where(active, table[jnp.arange(r), cur_slot],
                             pcfg.scratch_page)
        offset = pos % ps

        def body(carry, scanned):
            x, aux = carry
            lp, kp, vp = scanned
            dt = x.dtype
            y = L.rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
            q, k, v = L.project_qkv(lp["attn"], cfg, y, positions)
            kp = PC.write_token_kv(kp, k[:, 0], page_idx, offset)
            vp = PC.write_token_kv(vp, v[:, 0], page_idx, offset)
            if window:
                out = _attend_window(cfg, pcfg, q, kp, vp, table, pos,
                                     positions, dt, window=window,
                                     use_kernel=use_kernel)
            else:
                out = _attend_full(cfg, q, kp, vp, table, pos, positions, dt)
            h = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"].astype(dt))
            x = TF._constrain(x + h, flags)
            y2 = L.rmsnorm(x, lp["ln_mlp"], cfg.norm_eps)
            if cfg.is_moe:
                out2, a = MOE.moe_block(lp["moe"], cfg, y2)
            else:
                out2, a = L.mlp_block(lp["mlp"], y2), 0.0
            x = TF._constrain(x + out2, flags)
            return (x, aux + a), (kp, vp)

        (x, _), (k_pool, v_pool) = SU.scan(
            body, (x, 0.0), (params["layers"], k_pool, v_pool))
        logits = TF.lm_logits(cfg, params, x)                 # (R, 1, V)
        pos_next = jnp.where(active, pos + 1, pos)
        out = (sample_tokens(logits[:, -1, :], sample, key), pos_next,
               k_pool, v_pool)
        if check_finite:
            finite = jnp.all(jnp.isfinite(
                logits[:, -1, :].astype(jnp.float32)), axis=-1)
            return out + (finite,)
        return out

    return step


def make_paged_prefill_step(cfg: ArchConfig, pcfg: PagedCacheConfig,
                            bucket_pages: int,
                            flags: TF.RunFlags = TF.DEFAULT_FLAGS, *,
                            sample: SampleConfig = SampleConfig()):
    """One-request prefill for prompts bucketed to ``bucket_pages`` pages:
    ``(params, k_pool, v_pool, tokens (1, bucket), true_len, page_ids
    (bucket_pages,), key) -> (token (1,), k_pool, v_pool)``.

    Runs the stock training-path stack (`TF.attn_stack` with collect_kv) on
    the padded prompt — causal masking keeps real positions blind to the
    pad tail — then scatters the collected KV into the request's pages and
    reads logits at the true last position."""
    ps = pcfg.page_size
    bucket = bucket_pages * ps

    def prefill(params, k_pool, v_pool, tokens, true_len, page_ids, key):
        x = TF.embed_input(cfg, params, {"tokens": tokens})   # (1, bucket, d)
        positions = jnp.arange(bucket)
        x, _, kvs = TF.attn_stack(cfg, flags, params["layers"], x, positions,
                                  collect_kv=True)
        nl = cfg.n_layers
        k_new, v_new = kvs
        k_new = k_new[:, 0].reshape(nl, bucket_pages, ps, *k_new.shape[3:])
        v_new = v_new[:, 0].reshape(nl, bucket_pages, ps, *v_new.shape[3:])
        k_pool = k_pool.at[:, page_ids].set(k_new.astype(k_pool.dtype))
        v_pool = v_pool.at[:, page_ids].set(v_new.astype(v_pool.dtype))
        last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
        logits = TF.lm_logits(cfg, params, last)              # (1, 1, V)
        return sample_tokens(logits[:, -1, :], sample, key), k_pool, v_pool

    return prefill


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class StepEngine:
    """Device-state owner for continuous-batching serving.

    Host-side state (page tables, per-slot positions, the allocator) is
    plain numpy — it changes on admission/eviction, between jitted calls.
    Device state (pools, last tokens) stays on device across the whole run;
    nothing round-trips to host per token.
    """

    def __init__(self, cfg: ArchConfig, params, pcfg: PagedCacheConfig,
                 flags: TF.RunFlags = TF.DEFAULT_FLAGS, *,
                 sample: SampleConfig = SampleConfig(),
                 use_kernel: bool = False,
                 replica: ParamReplica | None = None,
                 mesh=None, seed: int = 0,
                 check_finite: bool = False):
        self.cfg, self.pcfg, self.flags = cfg, pcfg, flags
        self.window = validate_paged_support(cfg)
        self.sample = sample
        self.replica = replica
        self.check_finite = check_finite
        self._static_params = params
        self.alloc = PageAllocator(pcfg)
        r, n_table = pcfg.max_requests, pcfg.max_pages_per_seq
        self.table = np.full((r, n_table), pcfg.scratch_page, np.int32)
        self.pos = np.zeros((r,), np.int32)
        self.active = np.zeros((r,), bool)
        self.slot_rid: list = [None] * r
        self._slot_of: dict = {}
        k_pool, v_pool = PC.init_page_pool(
            cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim, pcfg,
            flags.kv_cache_dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.dist.sharding import paged_cache_specs
            spec = paged_cache_specs(cfg, mesh, k_pool)
            k_pool = jax.device_put(k_pool, NamedSharding(mesh, spec))
            v_pool = jax.device_put(v_pool, NamedSharding(mesh, spec))
        self.k_pool, self.v_pool = k_pool, v_pool
        self.tokens = jnp.zeros((r,), jnp.int32)
        # device mirrors of the membership state: pos advances in-jit, and
        # table/active/pos re-upload lazily (one coalesced transfer before
        # the next decode, however many admissions/evictions happened) — the
        # steady-state decode loop dispatches with zero host->device copies
        self._d_pos = jnp.zeros((r,), jnp.int32)
        self._d_table = jnp.asarray(self.table)
        self._d_active = jnp.asarray(self.active)
        self._dirty = False
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            make_paged_decode_step(cfg, pcfg, flags, window=self.window,
                                   sample=sample, use_kernel=use_kernel,
                                   check_finite=check_finite),
            donate_argnums=(1, 2))
        self._prefills: dict = {}
        self._finite = None           # (R,) device bools (check_finite only)
        self.steps = 0

    # -- capacity ----------------------------------------------------------
    @property
    def active_count(self) -> int:
        return int(self.active.sum())

    def has_slot(self) -> bool:
        return self.active_count < self.pcfg.max_requests

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        total = prompt_len + max_new
        if total > self.pcfg.max_pages_per_seq * self.pcfg.page_size:
            raise ValueError(
                f"request of {total} tokens exceeds per-request capacity")
        return self.has_slot() and self.alloc.can_alloc(
            self.pcfg.pages_needed(total))

    # -- params (direct or via the staleness-bounded replica) --------------
    def _params(self):
        if self.replica is not None:
            return self.replica.serving_params()
        return self._static_params

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- verbs -------------------------------------------------------------
    def start(self, rid, prompt: np.ndarray, max_new: int) -> jax.Array:
        """Admit ``rid``: allocate pages + a slot, prefill, emit the first
        token (returned as a device (1,) array — no host sync)."""
        prompt = np.asarray(prompt, np.int32)
        s = int(prompt.shape[0])
        assert s >= 1 and max_new >= 1
        n_pages = self.pcfg.pages_needed(s + max_new)
        bucket_pages = self.pcfg.pages_needed(s)
        pages = self.alloc.alloc(rid, n_pages)
        if pages is None:
            raise RuntimeError("admitted without pages (check can_admit)")
        slot = int(np.flatnonzero(~self.active)[0])
        self.table[slot] = self.pcfg.scratch_page
        self.table[slot, :n_pages] = pages
        if bucket_pages not in self._prefills:
            self._prefills[bucket_pages] = jax.jit(
                make_paged_prefill_step(self.cfg, self.pcfg, bucket_pages,
                                        self.flags, sample=self.sample),
                donate_argnums=(1, 2))
        padded = np.zeros((1, bucket_pages * self.pcfg.page_size), np.int32)
        padded[0, :s] = prompt
        tok, self.k_pool, self.v_pool = self._prefills[bucket_pages](
            self._params(), self.k_pool, self.v_pool, padded,
            np.int32(s), np.asarray(pages[:bucket_pages], np.int32),
            self._next_key())
        self.pos[slot] = s
        self.active[slot] = True
        self.slot_rid[slot] = rid
        self._slot_of[rid] = slot
        self.tokens = self.tokens.at[slot].set(tok[0])
        self._dirty = True
        return tok

    def step(self) -> jax.Array:
        """One decode step for every active slot; returns the (R,) device
        token array (row r is meaningful iff slot r is active)."""
        if self._dirty:
            # host pos mirrors device pos exactly (incremented below in
            # lockstep with the in-jit advance), so one upload restores all
            # three membership arrays after any number of start/finish calls
            self._d_pos = jnp.asarray(self.pos)
            self._d_table = jnp.asarray(self.table)
            self._d_active = jnp.asarray(self.active)
            self._dirty = False
        key = self._key if self.sample.is_greedy else self._next_key()
        out = self._decode(
            self._params(), self.k_pool, self.v_pool, self.tokens,
            self._d_pos, self._d_table, self._d_active, key)
        if self.check_finite:
            toks, self._d_pos, self.k_pool, self.v_pool, self._finite = out
        else:
            toks, self._d_pos, self.k_pool, self.v_pool = out
        self.tokens = toks
        self.pos[self.active] += 1
        self.steps += 1
        return toks

    def nonfinite_rids(self) -> list:
        """Requests whose last decode hit non-finite logits (empty unless
        ``check_finite``) — the scheduler's quarantine signal.  This is the
        one host sync the fault path pays, and only when armed."""
        if not self.check_finite or self._finite is None:
            return []
        flags = np.asarray(self._finite)
        return [self.slot_rid[s] for s in np.flatnonzero(self.active)
                if not flags[s] and self.slot_rid[s] is not None]

    def poison_kv(self, rid) -> None:
        """Fault injection: NaN the request's most recently written KV
        position.  Every live query attends that position, so the next
        decode step's logits go NaN for this slot — exactly the corruption
        the quarantine path must contain (`repro.faults`)."""
        slot = self._slot_of[rid]
        pos = int(self.pos[slot])
        if pos < 1:
            return
        page = int(self.table[slot, (pos - 1) // self.pcfg.page_size])
        off = (pos - 1) % self.pcfg.page_size
        self.k_pool = self.k_pool.at[:, page, off].set(jnp.nan)

    def finish(self, rid) -> None:
        """Evict ``rid``: free its pages and slot."""
        slot = self._slot_of.pop(rid)
        self.alloc.free(rid)
        self.table[slot] = self.pcfg.scratch_page
        self.pos[slot] = 0
        self.active[slot] = False
        self.slot_rid[slot] = None
        self._dirty = True

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]
