"""Continuous-batching request pump.

Static batching decodes a fixed batch until its *longest* request finishes —
head-of-line blocking proportional to the generation-length spread.  The
continuous scheduler instead re-decides membership every decode step:

    evict finished requests  ->  admit from the queue while a slot AND the
    pages fit  ->  one engine step for whatever is active.

The pump is deliberately blind to the model: it talks to anything with the
`StepEngine` verb surface (``can_admit`` / ``start`` / ``step`` /
``finish``), which is what the hypothesis property tests exploit (a fake
engine checks the scheduler never over-admits, never double-finishes, and
never leaks a page — mirroring the delivery-ring conservation tests).

Time is the virtual step clock (1 tick = 1 decode step): arrivals, queueing
delay and per-request latency are all measured in steps, so traces replay
deterministically and latency percentiles are machine-independent.

Tokens never round-trip to host during the run: the pump keeps the engine's
per-step (R,) device arrays plus (step, slot) coordinates per request, and
``drain`` materializes everything with ONE device->host fetch at the end.

Graceful degradation (`repro.faults`):

  * **retry-after backpressure** — a full queue still rejects ``submit``
    (the bound is the bound), but the scheduler now advertises
    ``retry_after`` (ticks until capacity is plausible) and ``run()``
    re-enqueues rejected arrivals at ``clock + retry_after`` instead of
    silently dropping them: every request in a trace eventually completes,
    and the pressure is visible as ``rejected_frac`` in :meth:`stats`.
  * **NaN quarantine** — with ``quarantine=True`` and an engine exposing
    ``nonfinite_rids()``, a request whose decode hit non-finite logits is
    evicted and requeued ONCE (from scratch — its poisoned KV pages are
    freed); a second offense marks its completion ``failed`` rather than
    letting it corrupt the batch forever.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int                  # tokens to generate (incl. prefill's)
    arrival: int = 0              # virtual step of arrival


@dataclass
class Completion:
    rid: int
    admitted: int                 # step admitted (prefill step)
    finished: int                 # step the last token was emitted
    tokens: np.ndarray | None = None
    failed: bool = False          # quarantined twice; tokens stay None


class ContinuousScheduler:
    """Bounded-admission continuous-batching pump over a `StepEngine`."""

    def __init__(self, engine, *, queue_limit: int = 64,
                 quarantine: bool = False, on_tick=None):
        self.engine = engine
        self.queue_limit = queue_limit
        self.quarantine = quarantine
        self.on_tick = on_tick        # fault-injection hook (repro.faults)
        self.queue: deque = deque()
        self.clock = 0
        self.submitted = 0
        self.rejected = 0
        self.resubmitted = 0
        self.quarantined = 0
        self.failed = 0
        self.retry_after = 1          # backpressure hint for rejected submits
        self._emitted: dict = {}      # rid -> tokens emitted so far
        self._live: dict = {}         # rid -> Request (admitted, not done)
        self._first_tok: dict = {}    # rid -> (1,) device array
        self._coords: dict = {}       # rid -> list of (step_idx, slot)
        self._step_log: list = []     # per engine step: (R,) device tokens
        self._qcount: dict = {}       # rid -> times quarantined
        self.completions: dict = {}   # rid -> Completion
        self.latencies: list = []     # (finished - arrival) per request

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; False (rejected) when the queue is full.  A
        rejection updates :attr:`retry_after` — come back in that many
        ticks (the queue drains at roughly one admission per tick, so the
        hint is the current backlog, bounded to stay responsive)."""
        self.submitted += 1
        if len(self.queue) >= self.queue_limit:
            self.rejected += 1
            self.retry_after = max(1, min(len(self.queue), 8))
            return False
        self.queue.append(req)
        return True

    def _admit(self) -> None:
        while self.queue:
            req = self.queue[0]
            if not self.engine.can_admit(len(req.prompt), req.max_new):
                break                 # FIFO: no skip-ahead past the head
            self.queue.popleft()
            tok = self.engine.start(req.rid, req.prompt, req.max_new)
            self._live[req.rid] = req
            self._emitted[req.rid] = 1          # prefill emits token 1
            self._first_tok[req.rid] = tok
            self._coords[req.rid] = []
            self.completions[req.rid] = Completion(
                rid=req.rid, admitted=self.clock, finished=-1)
            if self._emitted[req.rid] >= req.max_new:
                self._finish(req.rid)

    def _finish(self, rid) -> None:
        self.engine.finish(rid)
        req = self._live.pop(rid)
        self.completions[rid].finished = self.clock
        self.latencies.append(self.clock - req.arrival)

    def _quarantine(self, rid) -> None:
        """Evict a poisoned request; requeue once (at the queue head — it
        was wronged, not late), fail it on the second offense."""
        self.engine.finish(rid)       # frees the poisoned KV pages
        req = self._live.pop(rid)
        del self._emitted[rid], self._first_tok[rid], self._coords[rid]
        if self._qcount.get(rid, 0) >= 1:
            comp = self.completions[rid]
            comp.finished = self.clock
            comp.failed = True
            self.failed += 1
            return
        del self.completions[rid]     # readmission rebuilds it
        self._qcount[rid] = 1
        self.quarantined += 1
        self.queue.appendleft(req)

    # -- the pump ----------------------------------------------------------
    def step(self) -> None:
        """One tick: admit, then one decode step for the active set."""
        if self.on_tick is not None:
            self.on_tick(self)
        self._admit()
        if self._live:
            toks = self.engine.step()
            bad = ()
            if self.quarantine and hasattr(self.engine, "nonfinite_rids"):
                bad = tuple(self.engine.nonfinite_rids())
            for rid in bad:
                if rid in self._live:
                    self._quarantine(rid)
            idx = len(self._step_log)
            self._step_log.append(toks)
            for rid, req in list(self._live.items()):
                self._coords[rid].append((idx, self.engine.slot_of(rid)))
                self._emitted[rid] += 1
                if self._emitted[rid] >= req.max_new:
                    self._finish(rid)
        self.clock += 1

    def run(self, trace: list[Request], *, max_steps: int = 100_000) -> dict:
        """Replay an arrival trace to completion; returns rid -> tokens.

        Rejected arrivals are NOT dropped: they come back ``retry_after``
        ticks later (original arrival kept, so their measured latency
        includes the backpressure wait)."""
        pending = [(r.arrival, i, r)
                   for i, r in enumerate(
                       sorted(trace, key=lambda r: (r.arrival, r.rid)))]
        heapq.heapify(pending)
        seq = len(pending)
        while pending or self.queue or self._live:
            while pending and pending[0][0] <= self.clock:
                _, _, req = heapq.heappop(pending)
                if not self.submit(req):
                    self.resubmitted += 1
                    heapq.heappush(
                        pending, (self.clock + self.retry_after, seq, req))
                    seq += 1
            self.step()
            if self.clock > max_steps:
                raise RuntimeError(
                    f"scheduler did not drain in {max_steps} steps")
        return self.drain()

    def drain(self) -> dict:
        """Materialize every request's tokens: ONE host fetch for the whole
        run (the per-step arrays were device-resident throughout).  The
        stacked step log AND every request's first token are pulled in a
        single batched ``jax.device_get`` — the old per-request
        ``np.asarray(self._first_tok[rid])`` pulls were one device->host
        sync each (flagged by `repro.analysis`'s transfer detector; the
        coalesced fetch is pinned by ``tests/test_serve.py``).  Failed
        (twice-quarantined) requests keep ``tokens=None`` and are excluded
        from the result; their count is in :meth:`stats`."""
        if self._step_log:
            stacked = jnp.stack(self._step_log)               # (steps, R)
        else:
            stacked = np.zeros((0, 0), np.int32)
        all_tok, firsts = jax.device_get((stacked, self._first_tok))
        all_tok = np.asarray(all_tok)
        out = {}
        for rid, comp in self.completions.items():
            if comp.failed:
                continue
            first = np.asarray(firsts[rid])                   # (1,) host copy
            rest = np.array([all_tok[i, s] for i, s in self._coords[rid]],
                            np.int32)
            comp.tokens = np.concatenate([first, rest])
            out[rid] = comp.tokens
        return out

    # -- metrics -----------------------------------------------------------
    def latency_percentiles(self) -> tuple[float, float]:
        if not self.latencies:
            return 0.0, 0.0
        arr = np.asarray(self.latencies, np.float64)
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))

    def stats(self) -> dict:
        """Backpressure/fault accounting.  ``rejected_frac`` is rejections
        over submit attempts (retries count as attempts) — the bench rows
        gate on it so silent-rejection regressions show up."""
        p50, p99 = self.latency_percentiles()
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "resubmitted": self.resubmitted,
            "quarantined": self.quarantined,
            "failed": self.failed,
            "rejected_frac": self.rejected / max(self.submitted, 1),
            "p50": p50,
            "p99": p99,
        }
