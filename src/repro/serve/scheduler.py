"""Continuous-batching request pump.

Static batching decodes a fixed batch until its *longest* request finishes —
head-of-line blocking proportional to the generation-length spread.  The
continuous scheduler instead re-decides membership every decode step:

    evict finished requests  ->  admit from the queue while a slot AND the
    pages fit  ->  one engine step for whatever is active.

The pump is deliberately blind to the model: it talks to anything with the
`StepEngine` verb surface (``can_admit`` / ``start`` / ``step`` /
``finish``), which is what the hypothesis property tests exploit (a fake
engine checks the scheduler never over-admits, never double-finishes, and
never leaks a page — mirroring the delivery-ring conservation tests).

Time is the virtual step clock (1 tick = 1 decode step): arrivals, queueing
delay and per-request latency are all measured in steps, so traces replay
deterministically and latency percentiles are machine-independent.

Tokens never round-trip to host during the run: the pump keeps the engine's
per-step (R,) device arrays plus (step, slot) coordinates per request, and
``drain`` materializes everything with ONE device->host fetch at the end.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int                  # tokens to generate (incl. prefill's)
    arrival: int = 0              # virtual step of arrival


@dataclass
class Completion:
    rid: int
    admitted: int                 # step admitted (prefill step)
    finished: int                 # step the last token was emitted
    tokens: np.ndarray | None = None


class ContinuousScheduler:
    """Bounded-admission continuous-batching pump over a `StepEngine`."""

    def __init__(self, engine, *, queue_limit: int = 64):
        self.engine = engine
        self.queue_limit = queue_limit
        self.queue: deque = deque()
        self.clock = 0
        self.rejected = 0
        self._emitted: dict = {}      # rid -> tokens emitted so far
        self._live: dict = {}         # rid -> Request (admitted, not done)
        self._first_tok: dict = {}    # rid -> (1,) device array
        self._coords: dict = {}       # rid -> list of (step_idx, slot)
        self._step_log: list = []     # per engine step: (R,) device tokens
        self.completions: dict = {}   # rid -> Completion
        self.latencies: list = []     # (finished - arrival) per request

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; False (rejected) when the queue is full."""
        if len(self.queue) >= self.queue_limit:
            self.rejected += 1
            return False
        self.queue.append(req)
        return True

    def _admit(self) -> None:
        while self.queue:
            req = self.queue[0]
            if not self.engine.can_admit(len(req.prompt), req.max_new):
                break                 # FIFO: no skip-ahead past the head
            self.queue.popleft()
            tok = self.engine.start(req.rid, req.prompt, req.max_new)
            self._live[req.rid] = req
            self._emitted[req.rid] = 1          # prefill emits token 1
            self._first_tok[req.rid] = tok
            self._coords[req.rid] = []
            self.completions[req.rid] = Completion(
                rid=req.rid, admitted=self.clock, finished=-1)
            if self._emitted[req.rid] >= req.max_new:
                self._finish(req.rid)

    def _finish(self, rid) -> None:
        self.engine.finish(rid)
        req = self._live.pop(rid)
        self.completions[rid].finished = self.clock
        self.latencies.append(self.clock - req.arrival)

    # -- the pump ----------------------------------------------------------
    def step(self) -> None:
        """One tick: admit, then one decode step for the active set."""
        self._admit()
        if self._live:
            toks = self.engine.step()
            idx = len(self._step_log)
            self._step_log.append(toks)
            for rid, req in list(self._live.items()):
                self._coords[rid].append((idx, self.engine.slot_of(rid)))
                self._emitted[rid] += 1
                if self._emitted[rid] >= req.max_new:
                    self._finish(rid)
        self.clock += 1

    def run(self, trace: list[Request], *, max_steps: int = 100_000) -> dict:
        """Replay an arrival trace to completion; returns rid -> tokens."""
        pending = deque(sorted(trace, key=lambda r: (r.arrival, r.rid)))
        while pending or self.queue or self._live:
            while pending and pending[0].arrival <= self.clock:
                self.submit(pending.popleft())
            self.step()
            if self.clock > max_steps:
                raise RuntimeError(
                    f"scheduler did not drain in {max_steps} steps")
        return self.drain()

    def drain(self) -> dict:
        """Materialize every request's tokens: ONE host fetch for the whole
        run (the per-step arrays were device-resident throughout)."""
        if self._step_log:
            all_tok = np.asarray(jnp.stack(self._step_log))   # (steps, R)
        else:
            all_tok = np.zeros((0, 0), np.int32)
        out = {}
        for rid, comp in self.completions.items():
            first = np.asarray(self._first_tok[rid])          # (1,)
            rest = np.array([all_tok[i, s] for i, s in self._coords[rid]],
                            np.int32)
            comp.tokens = np.concatenate([first, rest])
            out[rid] = comp.tokens
        return out

    # -- metrics -----------------------------------------------------------
    def latency_percentiles(self) -> tuple[float, float]:
        if not self.latencies:
            return 0.0, 0.0
        arr = np.asarray(self.latencies, np.float64)
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))
