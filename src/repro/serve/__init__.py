"""repro.serve: continuous-batching inference on a paged KV cache, with
staleness-bounded parameter replicas (the paper's elastic-consistency bound
applied to serving-time parameter freshness)."""
from repro.serve.engine import StepEngine, validate_paged_support  # noqa: F401
from repro.serve.paged_cache import (PageAllocator,  # noqa: F401
                                     PagedCacheConfig, init_page_pool)
from repro.serve.replica import ParamReplica  # noqa: F401
from repro.serve.sampling import SampleConfig, sample_tokens  # noqa: F401
from repro.serve.scheduler import ContinuousScheduler, Request  # noqa: F401
