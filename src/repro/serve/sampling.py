"""Token sampling policies for the serving path.

Greedy is the default everywhere (``SampleConfig()`` is greedy), so the
legacy decode tests and the paged-vs-dense parity oracle are untouched;
temperature / top-k sampling is opt-in and threaded through both the legacy
loop (`repro.dist.train.make_decode_step`) and the continuous engine
(`repro.serve.engine.StepEngine`).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SampleConfig:
    """temperature <= 0 means greedy (argmax); top_k == 0 means no top-k
    truncation (sample the full distribution)."""

    temperature: float = 0.0
    top_k: int = 0

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


def greedy_tokens(logits: jax.Array) -> jax.Array:
    """(B, V) logits -> (B,) int32 argmax tokens."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_tokens(logits: jax.Array, sc: SampleConfig,
                  key: jax.Array | None = None) -> jax.Array:
    """(B, V) last-position logits -> (B,) int32 tokens.

    Greedy configs never touch ``key`` (callers may pass None); sampled
    configs scale by temperature, optionally truncate to the top-k logits
    (the rest masked to NEG_INF), and draw with ``jax.random.categorical``.
    """
    if sc.is_greedy:
        return greedy_tokens(logits)
    assert key is not None, "sampled decoding needs a PRNG key"
    scaled = logits.astype(jnp.float32) / sc.temperature
    if sc.top_k > 0 and sc.top_k < logits.shape[-1]:
        kth = jnp.sort(scaled, axis=-1)[:, -sc.top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, NEG_INF)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
