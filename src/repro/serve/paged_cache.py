"""Paged/block KV cache: fixed page pool + per-request page tables.

The dense serving cache is ``(L, B, max_len, K, hd)`` — every request pays
for the longest request's worth of KV slots up front.  The paged cache
replaces it with a fixed pool of ``num_pages`` pages of ``page_size`` tokens
each (vLLM-style), shared by all in-flight requests: a request holds only
``ceil((prompt + max_new) / page_size)`` pages, so mixed-length traffic
packs densely and admission capacity is a *page* budget, not a batch-slot
budget.

Layout
------
  * pools: k/v each ``(L, num_pages + 1, page_size, K, hd)``.  Page index
    ``num_pages`` is the **scratch page**: inactive request slots route
    their decode writes there (a jitted step always writes R rows; the
    scratch page absorbs the garbage so no real page is ever corrupted).
  * page table: ``(R, max_pages_per_seq)`` int32 per request slot; unused
    entries point at the scratch page, so a full-table gather of an
    inactive slot reads only trash that positional masking discards.
  * allocation is host-side (`PageAllocator`): a free list with
    all-or-nothing grants and double-free/leak detection — the device never
    sees allocation state, only tables.

Device ops here are *per layer* (the engine maps them over the layer dim
inside its ``lax.scan``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry. ``num_pages`` excludes the scratch page (the pool
    arrays carry ``num_pages + 1`` pages)."""

    page_size: int = 16
    num_pages: int = 64
    max_requests: int = 8        # request slots (R) in the jitted step
    max_pages_per_seq: int = 16  # page-table width per slot

    @property
    def scratch_page(self) -> int:
        return self.num_pages

    @property
    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def pages_needed(self, total_len: int) -> int:
        """Pages for a request of ``total_len = prompt + max_new`` tokens."""
        n = -(-total_len // self.page_size)
        if n > self.max_pages_per_seq:
            raise ValueError(
                f"request of {total_len} tokens needs {n} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        return n


def init_page_pool(n_layers: int, n_kv_heads: int, head_dim: int,
                   pcfg: PagedCacheConfig, dtype=jnp.bfloat16):
    """Zeroed (k_pages, v_pages), each (L, P+1, page_size, K, hd)."""
    shape = (n_layers, pcfg.num_pages + 1, pcfg.page_size, n_kv_heads,
             head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


class PageAllocator:
    """Host-side free-list allocator with leak/double-free detection.

    Grants are all-or-nothing: ``alloc`` returns None (and takes nothing)
    when fewer than ``n`` pages are free, so a request never holds a partial
    allocation the scheduler would have to unwind.
    """

    def __init__(self, pcfg: PagedCacheConfig):
        self.pcfg = pcfg
        self._free: list[int] = list(range(pcfg.num_pages))
        self._owned: dict = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, rid, n: int):
        """Grant ``n`` pages to request ``rid``; None if not available."""
        if rid in self._owned:
            raise ValueError(f"request {rid!r} already holds pages")
        if n <= 0:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            return None
        pages, self._free = self._free[:n], self._free[n:]
        self._owned[rid] = pages
        return list(pages)

    def free(self, rid) -> int:
        """Return ``rid``'s pages to the pool; raises on double-free."""
        if rid not in self._owned:
            raise ValueError(f"request {rid!r} holds no pages (double free?)")
        pages = self._owned.pop(rid)
        self._free.extend(pages)
        return len(pages)

    def check(self) -> None:
        """Invariant: free + owned partition the pool (no leak, no dup)."""
        seen = list(self._free)
        for pages in self._owned.values():
            seen.extend(pages)
        assert sorted(seen) == list(range(self.pcfg.num_pages)), (
            "page pool leak/duplication", sorted(seen))


# ---------------------------------------------------------------------------
# per-layer device ops (the engine vmaps/scans these over L)
# ---------------------------------------------------------------------------

def write_token_kv(pages: jax.Array, new: jax.Array, page_idx: jax.Array,
                   offset: jax.Array) -> jax.Array:
    """Scatter one token's KV per request slot into a (P+1, ps, K, hd) pool.

    new: (R, K, hd); page_idx/offset: (R,).  Rows of inactive slots must
    point page_idx at the scratch page (collisions there are harmless —
    scratch contents are never read unmasked)."""
    return pages.at[page_idx, offset].set(new.astype(pages.dtype))


def gather_all(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Full-table gather: (P+1, ps, K, hd), (R, n) -> (R, n*ps, K, hd).

    Token j of the result is absolute position j — with the table's pages
    in order, this reproduces the dense cache layout exactly (the bitwise
    parity path for full attention)."""
    r, n = table.shape
    out = pages[table]                       # (R, n, ps, K, hd)
    return out.reshape(r, n * pages.shape[1], *pages.shape[2:])


def window_slots(pos: jax.Array, window: int, pcfg: PagedCacheConfig,
                 n_table: int):
    """Which table slots a windowed decode read must touch.

    For a query at ``pos`` the live keys are [pos-window+1, pos]: that span
    crosses at most ``n_win = ceil(window / ps) + 1`` pages.  Returns
    (start (R,), n_win) with start clipped so the static-width slice stays
    in-table; the slice [start, start+n_win) always covers the window
    (tokens below it are dead, tokens above ``pos`` are masked)."""
    ps = pcfg.page_size
    n_win = min(-(-window // ps) + 1, n_table)
    start = jnp.clip(pos // ps - (n_win - 1), 0, n_table - n_win)
    return start, n_win


def gather_window(pages: jax.Array, table: jax.Array, start: jax.Array,
                  n_win: int):
    """Windowed gather: only ``n_win`` live pages per request.

    Returns (keys (R, n_win*ps, K, hd), base (R,)) where ``base`` is the
    absolute position of each row's token 0 — the kernel/oracle mask with
    ``key_pos = base + j``."""
    slots = jax.vmap(
        lambda row, s: jax.lax.dynamic_slice_in_dim(row, s, n_win))(
            table, start)                     # (R, n_win)
    out = pages[slots]                        # (R, n_win, ps, K, hd)
    r = table.shape[0]
    ps = pages.shape[1]
    return out.reshape(r, n_win * ps, *pages.shape[2:]), start * ps
