"""Bounded-staleness serving replica: params behind a `core.delivery` ring.

The paper's elastic-consistency bound says SGD converges as long as the
view each consumer reads lags the latest iterate by at most tau rounds.
Serving mid-training is the same relaxation applied at inference: the
trainer *publishes* each new parameter version into a version ring of
capacity ``tau_serve + 1`` (`repro.core.delivery.tree_ring_put` — overwrite
semantics, unlike the accumulating gradient rings), and the replica *serves*
from a slot at most ``tau_serve`` versions behind.  The bound is enforced
structurally: the ring only ever holds the last ``tau_serve + 1`` versions,
and `refresh` clamps the serving version into that window, so
``staleness <= tau_serve`` is an invariant, not a hope.

Which version inside the window the replica serves is drawn from the same
oblivious staleness schedules the async trainer uses
(`delivery.make_tau_schedule`), so a serving run can replay the exact
straggler/crash patterns the training-side experiments use (DROPPED entries
mean "refresh missed entirely" and pin the replica at maximal allowed lag).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delivery import (DROPPED, make_tau_schedule, tree_ring_init,
                                 tree_ring_put, tree_ring_read)


def _all_finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree.leaves(tree))


class ParamReplica:
    """Version ring of parameter snapshots with a hard staleness cap."""

    def __init__(self, params, tau_serve: int, *, schedule: str = "uniform",
                 horizon: int = 1024, seed: int = 0, lags=None):
        """``lags`` (optional int sequence) overrides the named schedule
        with an explicit per-refresh lag trace — `repro.analysis.rings`
        drives the model checker's exhaustively-enumerated schedules
        through the real replica with it."""
        if tau_serve < 0:
            raise ValueError(f"tau_serve must be >= 0, got {tau_serve}")
        self.tau_serve = tau_serve
        self.capacity = tau_serve + 1
        # version 0 = the params the replica was brought up with
        self.rings = tree_ring_put(
            tree_ring_init(self.capacity, params), 0, params)
        self.latest_version = 0
        self.serving_version = 0
        if not _all_finite(params):
            raise ValueError("replica bootstrap params contain non-finite "
                             "leaves — nothing safe to serve")
        if lags is None:
            lags = make_tau_schedule(schedule, 1, horizon, tau_serve,
                                     seed)[:, 0]
        lags = np.asarray(lags, np.int64)
        if lags.size == 0 or np.any((lags != DROPPED)
                                    & ((lags < 0) | (lags > tau_serve))):
            raise ValueError(f"lags must be in [0, {tau_serve}] or DROPPED")
        # DROPPED refresh = the replica missed the round: maximal legal lag
        self._lags = np.where(lags == DROPPED, tau_serve, lags)
        self._refreshes = 0
        self.refused = 0

    @property
    def staleness(self) -> int:
        return self.latest_version - self.serving_version

    def publish(self, params, version: int | None = None) -> int | None:
        """Trainer side: install a new version (defaults to latest + 1).

        Overwrites the ring slot ``version % capacity`` — the version that
        falls out of the window is exactly the one no replica may serve
        anymore (it would exceed ``tau_serve``).

        A version containing non-finite leaves is **refused** (returns
        None, bumps :attr:`refused`): the ring, ``latest_version`` and the
        staleness floor are untouched, so the replica keeps serving the
        last healthy snapshot while training recovers — poisoned params
        must never enter the window, or the floor itself would force
        serving them."""
        if not _all_finite(params):
            self.refused += 1
            return None
        v = self.latest_version + 1 if version is None else version
        if v != self.latest_version + 1:
            raise ValueError(
                f"publish must advance by 1: {self.latest_version} -> {v}")
        self.rings = tree_ring_put(self.rings, v % self.capacity, params)
        self.latest_version = v
        # the slot just overwritten held v - capacity; if we were serving it,
        # the floor below bumps us forward at the next read
        self.serving_version = max(self.serving_version,
                                   self.latest_version - self.tau_serve)
        return v

    def refresh(self) -> int:
        """Replica side: pick the serving version for the next requests.

        The scheduled lag is clamped into the legal window
        ``[latest - tau_serve, latest]`` (and below by what was ever
        published); serving never moves backwards."""
        lag = int(self._lags[self._refreshes % len(self._lags)])
        self._refreshes += 1
        want = self.latest_version - min(lag, self.tau_serve)
        self.serving_version = max(self.serving_version, want, 0)
        return self.serving_version

    def serving_params(self):
        """The snapshot for ``serving_version`` (read, never consumed)."""
        assert 0 <= self.staleness <= self.tau_serve, (
            self.latest_version, self.serving_version)
        return tree_ring_read(self.rings,
                              self.serving_version % self.capacity)
