"""Shared contract between the two simulator engines (`sim_ref`, `sim_engine`).

This module owns the three pieces both engines must agree on exactly:

  * :class:`Relaxation` / :class:`SimResult` — the public API types,
  * :func:`make_schedule` / :func:`make_shared_memory_schedule` — the
    *oblivious-adversary* scheduling randomness, pre-drawn into dense arrays.

Oblivious-adversary RNG layout
------------------------------
The paper assumes the scheduler cannot look at the gradients it delays
(§4.1).  We realize that literally by drawing **all** scheduling randomness
up-front from ``np.random.default_rng(seed)`` — a stream that never sees a
gradient — while gradient sampling uses an independent
``jax.random.PRNGKey(seed + 1)`` stream: problems exposing
``presample_grads`` (both built-in testbeds; their gradient stochasticity is
iterate-independent) have all T steps' draws materialized in one batched
call at that key, otherwise the engines fall back to one ``split`` per step.
Because the schedule is a plain array pytree, the numpy oracle indexes it
per step while the ``lax.scan`` engine feeds the per-step slices through
``scan`` ``xs`` — the two engines consume *identical* randomness, which is
what makes the step-for-step parity suite possible.

Draw order (fixed; changing it is a semantic break for seeded runs):

  crash / crash_subst : choice(p, f) crash ids -> integers crash times ->
                        uniform (f, p) "who hears the last broadcast"
  omission            : uniform (T, p, p) drop draws -> integers (T, p, p)
                        extra delivery delays in {0, 1}
  async               : integers (T, p, p) per-message delays in [0, tau_max)
  elastic_norm        : uniform (T, p, p) -> argsort = per-worker arrival
                        permutations
  elastic_variance    : uniform (T, p, p) drop draws
  adversarial         : normal (d,) displacement direction (normalized)
  shared memory       : integers (T, d) componentwise staleness in
                        [0, tau_max)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import compression as C


@dataclass(frozen=True)
class Relaxation:
    """Which consistency relaxation to simulate.

    kind:
      sync              — failure-free synchronous baseline (B = 0)
      crash             — Alg 2: f crash faults, no substitution
      crash_subst       — Alg 1: crash faults, receivers substitute own grad
      omission          — Alg 3: <= f outstanding delayed messages
      async             — B.4: per-message delay < tau_max
      ef_comp           — Alg 6: error-feedback compression (all-delivered)
      elastic_norm      — §5 norm-bounded scheduler (beta)
      elastic_variance  — Alg 4: 1-step delays, substitute-then-correct
      adversarial       — Lemma 6 oracle: view displaced by alpha*B
    """

    kind: str = "sync"
    f: int = 0                   # crash/omission fault bound
    tau_max: int = 1             # async delay bound
    drop_prob: float = 0.3       # per-message delay probability
    compressor: Optional[C.Compressor] = None
    beta: float = 0.8            # norm-bounded scheduler threshold
    B_adv: float = 0.0           # adversarial oracle displacement


@dataclass
class SimResult:
    losses: np.ndarray           # recorded every `record_every`
    grad_norms2: np.ndarray      # ||grad f(x_t)||^2 at the same cadence
    gap2_over_alpha2: np.ndarray # max_i ||x_t - v_t^i||^2 / alpha^2, per step
    x_final: np.ndarray
    record_every: int
    alpha: float

    @property
    def b_hat(self) -> float:
        """Empirical elastic-consistency constant sqrt(max_t E gap^2/a^2)."""
        return float(np.sqrt(np.max(self.gap2_over_alpha2)))

    @property
    def b_hat_mean(self) -> float:
        return float(np.sqrt(np.mean(self.gap2_over_alpha2)))


@dataclass
class Schedule:
    """Pre-drawn scheduling randomness. ``per_step`` arrays have leading dim
    T (fed as ``lax.scan`` xs); ``per_run`` arrays are constant over the
    run (crash times, adversarial direction)."""

    per_step: dict
    per_run: dict


def make_schedule(relax: Relaxation, p: int, d: int, T: int,
                  seed: int) -> Schedule:
    """Draw the full schedule for one run (layout documented above)."""
    rng = np.random.default_rng(seed)
    per_step: dict = {}
    per_run: dict = {}
    kind = relax.kind

    if kind.startswith("crash"):
        if not 0 <= relax.f < p:
            raise ValueError(
                f"crash fault bound f={relax.f} must satisfy 0 <= f < p={p} "
                "(at least one worker must survive)")
        crashed = rng.choice(p, size=relax.f, replace=False)
        times = rng.integers(1, max(T - 1, 2), size=relax.f)
        hear_u = rng.random((relax.f, p))
        crash_step = np.full(p, T, np.int32)          # T == never crashes
        hear = np.ones((p, p), np.float32)            # row j: j's broadcast
        crash_step[crashed] = times
        hear[crashed] = hear_u
        per_run["crash_step"] = crash_step
        per_run["hear_u"] = hear
    elif kind == "omission":
        per_step["drop_u"] = rng.random((T, p, p)).astype(np.float32)
        per_step["extra_delay"] = rng.integers(
            0, 2, size=(T, p, p)).astype(np.int32)
    elif kind == "async":
        delays = rng.integers(0, relax.tau_max,
                              size=(T, p, p)).astype(np.int32)
        delays[:, np.arange(p), np.arange(p)] = 0     # own grad is immediate
        per_step["delays"] = delays
    elif kind == "elastic_norm":
        per_step["perm"] = np.argsort(
            rng.random((T, p, p)), axis=-1).astype(np.int32)
    elif kind == "elastic_variance":
        per_step["drop_u"] = rng.random((T, p, p)).astype(np.float32)
    elif kind == "adversarial":
        adv = rng.normal(size=d).astype(np.float32)
        per_run["adv_dir"] = adv / np.linalg.norm(adv)
    elif kind in ("sync", "ef_comp"):
        pass
    else:
        raise ValueError(kind)
    return Schedule(per_step, per_run)


def make_shared_memory_schedule(p: int, d: int, T: int, tau_max: int,
                                seed: int) -> Schedule:
    rng = np.random.default_rng(seed)
    taus = rng.integers(0, tau_max, size=(T, d)).astype(np.int32)
    return Schedule({"taus": taus}, {})
