"""Device-resident ``lax.scan`` engine for the elastic-consistency simulator.

One pure, fully-jitted step function per relaxation kind; the whole T-step
run compiles to a single XLA program, so the host syncs **once per run**
instead of once per step (the numpy oracle in `sim_ref` pays a device
round-trip + ``float()`` sync every step).  Structural translation from the
oracle:

  * the per-worker Python loops become (p, p) boolean delivery matrices
    contracted against the (p, d) gradient stack on the MXU,
  * the dynamic ``pending`` list becomes fixed-capacity delay ring buffers —
    capacity is bounded by the relaxation itself (``tau_max`` for async,
    delay <= 2 for omission, 1 step for the elastic schedulers),
  * EF compression routes through the fused Pallas ``topk_ef``/``onebit_ef``
    kernels (interpret mode off-TPU) via ``compression.ef_compress_rows``
    instead of a per-worker dense loop,
  * gradient randomness is materialized in ONE batched ``presample_grads``
    draw before the scan (T sequential in-loop threefry calls are the
    dominant per-step cost on CPU) and enters as scan ``xs``; problems
    without ``presample_grads`` fall back to a per-step key-split chain,
  * losses/grad-norms are evaluated *after* the scan on the recorded
    trajectory in one vmapped call.

Scheduling randomness is the pre-drawn oblivious-adversary
:class:`~repro.core.sim_types.Schedule` (layout in `sim_types`); per-step
draws enter the scan as ``xs`` slices, so the engine consumes bit-identical
schedules to `sim_ref` — the parity suite checks trajectories step-for-step.

Compiled programs are cached on the problem object keyed by
(relaxation, p, T); ``alpha``, ``x0`` and the schedule are traced arguments,
so figure sweeps over step sizes or seeds never recompile.
:func:`simulate_sweep` vmaps one compiled program over stacked seeds for the
multi-seed figure sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core.sim_types import (Relaxation, Schedule, SimResult,
                                  make_schedule, make_shared_memory_schedule)

def _interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CPU CI).

    Evaluated lazily (at trace time, never at import): ``default_backend()``
    initializes the XLA backend, and launch scripts (`repro.launch.dryrun`)
    must be able to set XLA_FLAGS before that first initialization.
    """
    return jax.default_backend() != "tpu"


_CACHE_ATTR = "_sim_engine_cache"


def _cache(problem) -> dict:
    cache = getattr(problem, _CACHE_ATTR, None)
    if cache is None:
        cache = {}
        setattr(problem, _CACHE_ATTR, cache)
    return cache


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def _build_run(problem, relax: Relaxation, p: int, T: int):
    """Return run(x0, alpha, key, per_step, per_run) -> (xs, gaps/alpha^2).

    ``xs`` is the (T, d) trajectory of the auxiliary parameter x (post-step),
    recorded as scan outputs; the caller subsamples it for loss eval.
    """
    kind = relax.kind
    d = problem.dim
    eye = jnp.asarray(np.eye(p, dtype=bool))
    # fast path: iterate-independent gradient randomness is drawn in ONE
    # batched PRNG call before the scan (T sequential in-loop threefry calls
    # dominate the step cost on CPU otherwise) and enters as scan xs
    has_pre = hasattr(problem, "presample_grads")

    # fixed ring capacities, bounded by the relaxation semantics
    om_ring = 3                            # omission: delivery in {t+1, t+2}
    as_ring = max(relax.tau_max, 1)        # async: delay < tau_max

    def fmat(m):                           # bool (p,p) -> f32 for the MXU
        return m.astype(jnp.float32)

    def step(carry, xs):
        if has_pre:
            t, step_s, draw = xs
            grads_at = lambda views: problem.batch_grads_at(views, draw)
        else:
            t, step_s = xs
            carry["key"], sub = jax.random.split(carry["key"])
            grads_at = lambda views: problem.batch_grads(views, sub)
        x, v, alive = carry["x"], carry["v"], carry["alive"]
        scale = carry["alpha"] / p

        if kind == "adversarial":
            views = x[None] + carry["alpha"] * relax.B_adv * \
                carry["adv_dir"][None]
            g = grads_at(jnp.broadcast_to(views, (p, d)))
            x = x - scale * jnp.sum(g, 0)
            v = jnp.broadcast_to(x[None], (p, d))

        elif kind == "sync":
            g = grads_at(v)
            upd = scale * jnp.sum(g, 0)
            x = x - upd
            v = v - upd[None]

        elif kind in ("crash", "crash_subst"):
            g = grads_at(v)
            crashing = alive & (carry["crash_step"] == t)
            new_alive = alive & ~crashing
            # recv[i, j]: does i receive j's broadcast this step?
            base = alive[:, None] & alive[None, :]
            heard = (carry["hear_u"].T < 0.5) & new_alive[:, None] & ~eye
            recv = jnp.where(crashing[None, :], heard, base)
            in_recv = jnp.any(recv, axis=0)           # heard by >= 1 node
            x = x - scale * (fmat(in_recv) @ g)
            got = fmat(recv) @ g
            if kind == "crash_subst":
                missed = jnp.sum((~recv) & in_recv[None, :], axis=1)
                got = got + missed.astype(jnp.float32)[:, None] * g
            v = jnp.where(new_alive[:, None], v - scale * got, v)
            alive = new_alive

        elif kind == "omission":
            g = grads_at(v)
            ring, cnt = carry["ring"], carry["cnt"]
            cand = (step_s["drop_u"] < relax.drop_prob) & ~eye
            # first-come quota: at most f messages outstanding, row-major
            # (i, j) order — identical to the oracle's loop order
            cf = cand.reshape(-1)
            before = jnp.cumsum(cf) - cf
            take = (cf & (before < relax.f - jnp.sum(cnt))).reshape(p, p)
            gsum = jnp.sum(g, 0)
            x = x - scale * gsum
            v = v - scale * (gsum[None] - fmat(take) @ g)
            for e in (0, 1):                          # extra delay in {0, 1}
                m = take & (step_s["extra_delay"] == e)
                slot = (t + 1 + e) % om_ring
                ring = ring.at[slot].add(scale * (fmat(m) @ g))
                cnt = cnt.at[slot].add(jnp.sum(m))
            v = v - ring[t % om_ring]
            carry["ring"] = ring.at[t % om_ring].set(0.0)
            carry["cnt"] = cnt.at[t % om_ring].set(0)

        elif kind == "async":
            g = grads_at(v)
            delays = step_s["delays"]
            x = x - scale * jnp.sum(g, 0)
            v = v - scale * (fmat(delays == 0) @ g)
            if as_ring > 1:
                ring = carry["ring"]
                for dl in range(1, relax.tau_max):
                    m = delays == dl
                    ring = ring.at[(t + dl) % as_ring].add(
                        scale * (fmat(m) @ g))
                v = v - ring[t % as_ring]
                carry["ring"] = ring.at[t % as_ring].set(0.0)

        elif kind == "ef_comp":
            g = grads_at(v)
            payloads, carry["err"] = C.ef_compress_rows(
                relax.compressor, carry["alpha"] * g, carry["err"],
                interpret=_interpret())
            x = x - scale * jnp.sum(g, 0)
            v = v - jnp.sum(payloads, 0)[None] / p

        elif kind == "elastic_norm":
            g = grads_at(v)
            perm = step_s["perm"]                     # (p, p) arrival order
            norms = jnp.sqrt(jnp.sum(g * g, axis=1))
            self_m = perm == jnp.arange(p)[:, None]
            contrib = jnp.where(self_m, 0.0, norms[perm])
            acc_before = jnp.cumsum(contrib, axis=1) - contrib
            inc = (acc_before < relax.beta * norms[:, None]) | self_m
            recv = jnp.zeros((p, p), bool).at[
                jnp.arange(p)[:, None], perm].set(inc)
            gsum = jnp.sum(g, 0)
            recvg = fmat(recv) @ g
            x = x - scale * gsum
            v = v - scale * recvg - carry["defer"]
            carry["defer"] = scale * (gsum[None] - recvg)

        elif kind == "elastic_variance":
            g = grads_at(v)
            drop = (step_s["drop_u"] < relax.drop_prob) & ~eye
            nd = jnp.sum(drop, axis=1).astype(jnp.float32)[:, None]
            gsum = jnp.sum(g, 0)
            dropg = fmat(drop) @ g
            # keep@g = gsum - g - drop@g, so upd = gsum + nd*g - drop@g
            x = x - scale * gsum
            v = v - scale * (gsum[None] + nd * g - dropg) - carry["defer"]
            carry["defer"] = scale * (dropg - nd * g)

        else:
            raise ValueError(kind)

        carry["x"], carry["v"], carry["alive"] = x, v, alive
        sq = jnp.sum((x[None] - v) ** 2, axis=1)
        gap2 = jnp.max(jnp.where(alive, sq, -jnp.inf))
        return carry, (x, gap2)

    def run(x0, alpha, key, per_step, per_run):
        x0 = x0.astype(jnp.float32)
        carry = {"x": x0, "v": jnp.tile(x0, (p, 1)),
                 "alive": jnp.ones(p, bool), "alpha": alpha}
        xs_in = (jnp.arange(T), per_step)
        if has_pre:
            xs_in = xs_in + (problem.presample_grads(key, T, p),)
        else:
            carry["key"] = key
        if kind.startswith("crash"):
            carry["crash_step"] = per_run["crash_step"]
            carry["hear_u"] = per_run["hear_u"]
        if kind == "adversarial":
            carry["adv_dir"] = per_run["adv_dir"]
        if kind == "omission":
            carry["ring"] = jnp.zeros((om_ring, p, d), jnp.float32)
            carry["cnt"] = jnp.zeros(om_ring, jnp.int32)
        if kind == "async" and as_ring > 1:
            carry["ring"] = jnp.zeros((as_ring, p, d), jnp.float32)
        if kind == "ef_comp":
            carry["err"] = jnp.zeros((p, d), jnp.float32)
        if kind in ("elastic_norm", "elastic_variance"):
            carry["defer"] = jnp.zeros((p, d), jnp.float32)
        _, (xs, gaps2) = jax.lax.scan(step, carry, xs_in)
        return xs, gaps2 / (alpha * alpha)

    return run


def _build_shared_run(problem, p: int, T: int, tau_max: int):
    d = problem.dim
    has_pre = hasattr(problem, "presample_grads")

    def step(carry, xs):
        if has_pre:
            t, taus, draw = xs
            grads_at = lambda views: problem.batch_grads_at(views, draw)
        else:
            t, taus = xs
            carry["key"], sub = jax.random.split(carry["key"])
            grads_at = lambda views: problem.batch_grads(views, sub)
        x, hist, alpha = carry["x"], carry["hist"], carry["alpha"]
        idx = (t - taus) % (tau_max + 1)
        view = hist[idx, jnp.arange(d)]
        g = grads_at(view[None])[0]
        gap2 = jnp.sum((x - view) ** 2)
        x = x - alpha * g
        carry["x"] = x
        carry["hist"] = hist.at[(t + 1) % (tau_max + 1)].set(x)
        return carry, (x, gap2)

    def run(x0, alpha, key, per_step, per_run):
        del per_run
        x0 = x0.astype(jnp.float32)
        carry = {"x": x0, "hist": jnp.tile(x0, (tau_max + 1, 1)),
                 "alpha": alpha}
        xs_in = (jnp.arange(T), per_step["taus"])
        if has_pre:
            xs_in = xs_in + (problem.presample_grads(key, T, 1),)
        else:
            carry["key"] = key
        _, (xs, gaps2) = jax.lax.scan(step, carry, xs_in)
        return xs, gaps2 / (alpha * alpha)

    return run


# ---------------------------------------------------------------------------
# compiled-program cache + result assembly
# ---------------------------------------------------------------------------

def _get_run(problem, key_tup, builder, vmapped: bool):
    cache = _cache(problem)
    ck = ("vrun" if vmapped else "run",) + key_tup
    if ck not in cache:
        run = builder()
        if vmapped:
            run = jax.vmap(run, in_axes=(None, None, 0, 0, 0))
        cache[ck] = jax.jit(run)
    return cache[ck]


def _get_eval(problem):
    cache = _cache(problem)
    if "eval" not in cache:
        def ev(xs_rec):
            losses = jax.vmap(problem.loss)(xs_rec)
            gns = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(xs_rec)
            return losses, gns
        cache["eval"] = jax.jit(ev)
    return cache["eval"]


def _finalize(problem, xs, gaps2, alpha, record_every) -> SimResult:
    xs_rec = xs[::record_every]
    losses, gns = _get_eval(problem)(xs_rec)
    return SimResult(np.asarray(losses), np.asarray(gns),
                     np.asarray(gaps2, np.float64), np.asarray(xs[-1]),
                     record_every, alpha)


def _finalize_batch(problem, xs, gaps2, alpha, record_every) -> list:
    """Sweep finalize: ONE loss/grad eval + bulk transfer for all seeds
    (xs (S, T, d)), instead of S sequential dispatches and device syncs."""
    n, t, d = xs.shape
    xs_rec = xs[:, ::record_every]
    n_rec = xs_rec.shape[1]
    losses, gns = _get_eval(problem)(xs_rec.reshape(n * n_rec, d))
    losses = np.asarray(losses).reshape(n, n_rec)
    gns = np.asarray(gns).reshape(n, n_rec)
    gaps2 = np.asarray(gaps2, np.float64)
    x_fin = np.asarray(xs[:, -1])
    return [SimResult(losses[i], gns[i], gaps2[i], x_fin[i],
                      record_every, alpha) for i in range(n)]


def _as_device(schedule: Schedule):
    to_j = lambda tree: jax.tree.map(jnp.asarray, tree)
    return to_j(schedule.per_step), to_j(schedule.per_run)


def simulate_scan(problem, relax: Relaxation, p: int, alpha: float, T: int,
                  seed: int = 0, x0=None, record_every: int = 10,
                  schedule: Optional[Schedule] = None) -> SimResult:
    """Compiled equivalent of :func:`repro.core.sim_ref.simulate_ref`."""
    if schedule is None:
        schedule = make_schedule(relax, p, problem.dim, T, seed)
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    run = _get_run(problem, (relax, p, T),
                   lambda: _build_run(problem, relax, p, T), vmapped=False)
    per_step, per_run = _as_device(schedule)
    xs, gaps2 = run(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                    jax.random.PRNGKey(seed + 1), per_step, per_run)
    return _finalize(problem, xs, gaps2, alpha, record_every)


def simulate_sweep(problem, relax: Relaxation, p: int, alpha: float, T: int,
                   seeds, x0=None, record_every: int = 10) -> list:
    """vmap one compiled run over seeds: schedules and gradient keys get a
    leading seed axis; x0/alpha are broadcast. Returns [SimResult] per seed.
    """
    seeds = list(seeds)
    scheds = [make_schedule(relax, p, problem.dim, T, s) for s in seeds]
    per_step = jax.tree.map(lambda *a: jnp.asarray(np.stack(a)),
                            *[s.per_step for s in scheds])
    per_run = jax.tree.map(lambda *a: jnp.asarray(np.stack(a)),
                           *[s.per_run for s in scheds])
    keys = jnp.stack([jax.random.PRNGKey(s + 1) for s in seeds])
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    vrun = _get_run(problem, (relax, p, T),
                    lambda: _build_run(problem, relax, p, T), vmapped=True)
    xs, gaps2 = vrun(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                     keys, per_step, per_run)
    return _finalize_batch(problem, xs, gaps2, alpha, record_every)


def simulate_shared_memory_scan(problem, p: int, alpha: float, T: int,
                                tau_max: int, seed: int = 0, x0=None,
                                record_every: int = 10,
                                schedule: Optional[Schedule] = None
                                ) -> SimResult:
    if schedule is None:
        schedule = make_shared_memory_schedule(p, problem.dim, T, tau_max,
                                               seed)
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    run = _get_run(problem, ("shm", p, T, tau_max),
                   lambda: _build_shared_run(problem, p, T, tau_max),
                   vmapped=False)
    per_step, per_run = _as_device(schedule)
    xs, gaps2 = run(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                    jax.random.PRNGKey(seed + 1), per_step, per_run)
    return _finalize(problem, xs, gaps2, alpha, record_every)
