"""Device-resident ``lax.scan`` engine for the elastic-consistency simulator.

One pure, fully-jitted step function per relaxation kind; the whole T-step
run compiles to a single XLA program, so the host syncs **once per run**
instead of once per step (the numpy oracle in `sim_ref` pays a device
round-trip + ``float()`` sync every step).  Structural translation from the
oracle:

  * the per-worker Python loops become (p, p) boolean delivery matrices
    contracted against the (p, d) gradient stack on the MXU,
  * the dynamic ``pending`` list becomes fixed-capacity delay ring buffers
    (`repro.core.delivery` — shared with the real-model async engine in
    `repro.dist.async_engine`) — capacity is bounded by the relaxation
    itself (``tau_max`` for async, delay <= 2 for omission, 1 step for the
    elastic schedulers),
  * EF compression routes through the fused Pallas ``topk_ef``/``onebit_ef``
    kernels (interpret mode off-TPU) via ``compression.ef_compress_rows``
    instead of a per-worker dense loop,
  * gradient randomness is materialized in ONE batched ``presample_grads``
    draw before the scan (T sequential in-loop threefry calls are the
    dominant per-step cost on CPU) and enters as scan ``xs``; problems
    without ``presample_grads`` fall back to a per-step key-split chain,
  * losses/grad-norms are evaluated *after* the scan on the recorded
    trajectory in one vmapped call.

Fused fast path (``fused=True|"auto"``)
---------------------------------------
For the `Quadratic` testbed and the kinds in
:data:`repro.kernels.sim_step.FUSED_KINDS`, the per-step pipeline — view
gradients ``(V - x*) @ A + noise``, the delivery contraction, and the
averaging/apply update — collapses into one fused kernel call per step
(`repro.kernels.sim_step`): delivery tensors are precomputed for the whole
run in one vectorized pass (they are schedule-determined, never
iterate-dependent), and ``sync`` further degenerates to a single matvec
because every view equals ``x`` exactly.  Pallas kernel on TPU, the fused
jnp oracle elsewhere.  The unfused scan step is kept verbatim as the
parity oracle; ``fused="auto"`` (the default) switches the fast path on
exactly when it is supported.

Scheduling randomness is the pre-drawn oblivious-adversary
:class:`~repro.core.sim_types.Schedule` (layout in `sim_types`); per-step
draws enter the scan as ``xs`` slices, so the engine consumes bit-identical
schedules to `sim_ref` — the parity suite checks trajectories step-for-step.

Compiled programs are cached on the problem object keyed by
(relaxation statics, p, T, fused); ``alpha``, ``x0``, the schedule AND the
relaxation's float knobs (``drop_prob``/``beta``/``B_adv``) are traced
arguments, so figure sweeps over step sizes, seeds or scheduler knobs never
recompile.  :func:`simulate_sweep` vmaps one compiled program over stacked
seeds; :func:`simulate_grid` goes further and vmaps over stacked
*(problem, relaxation-knob, alpha, seed)* cases — same-shape (p, d)
instances become a leading batch axis of one compiled program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core import delivery as DLV
from repro.core.sim_types import (Relaxation, Schedule, SimResult,
                                  make_schedule, make_shared_memory_schedule)
from repro.kernels import sim_step as SSK


def _interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CPU CI).

    Evaluated lazily (at trace time, never at import): ``default_backend()``
    initializes the XLA backend, and launch scripts (`repro.launch.dryrun`)
    must be able to set XLA_FLAGS before that first initialization.
    """
    return jax.default_backend() != "tpu"


_CACHE_ATTR = "_sim_engine_cache"


def _cache(problem) -> dict:
    cache = getattr(problem, _CACHE_ATTR, None)
    if cache is None:
        cache = {}
        setattr(problem, _CACHE_ATTR, cache)
    return cache


def _static_key(relax: Relaxation) -> tuple:
    """The relaxation fields that shape the compiled program.  Float knobs
    (drop_prob/beta/B_adv) are traced and deliberately excluded."""
    return (relax.kind, relax.f, relax.tau_max, relax.compressor)


def _knob_values(relax: Relaxation) -> dict:
    """Traced float knobs, fed per-run so knob sweeps share one program."""
    return {"drop_prob": jnp.float32(relax.drop_prob),
            "beta": jnp.float32(relax.beta),
            "B_adv": jnp.float32(relax.B_adv)}


# "auto" engages the fused path only where it wins: below ~128 dims the
# gradient matmul is too cheap for the fusion to pay for itself (the
# BENCH_sim smoke grid at d=64 shows ~0.7-1x; d >= 256 shows 2-6x).
AUTO_MIN_DIM = 128


def _resolve_fused(problem, relax: Relaxation, fused) -> bool:
    if fused == "auto":
        return problem.dim >= AUTO_MIN_DIM and \
            SSK.supports_fused(problem, relax)
    if fused is True:
        if not SSK.supports_fused(problem, relax):
            raise ValueError(
                f"fused=True unsupported for kind={relax.kind!r} on "
                f"{type(problem).__name__} (needs quadratic sim_data and a "
                f"kind in {SSK.FUSED_KINDS})")
        return True
    if fused is False:
        return False
    raise ValueError(f"fused must be True, False or 'auto', got {fused!r}")


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def _build_run(problem, relax: Relaxation, p: int, T: int,
               fused: bool = False):
    """Return run(x0, alpha, key, per_step, per_run, knobs, data)
    -> (xs, gaps/alpha^2).

    ``xs`` is the (T, d) trajectory of the auxiliary parameter x (post-step),
    recorded as scan outputs; the caller subsamples it for loss eval.
    ``knobs`` are the relaxation's traced float knobs; ``data`` is the
    problem-as-pytree (fused path only — the unfused oracle step closes
    over the problem and ignores it).
    """
    if fused:
        return _build_fused_run(problem, relax, p, T)
    kind = relax.kind
    d = problem.dim
    eye = jnp.asarray(np.eye(p, dtype=bool))
    # fast path: iterate-independent gradient randomness is drawn in ONE
    # batched PRNG call before the scan (T sequential in-loop threefry calls
    # dominate the step cost on CPU otherwise) and enters as scan xs
    has_pre = hasattr(problem, "presample_grads")

    # fixed ring capacities, bounded by the relaxation semantics
    om_ring = 3                            # omission: delivery in {t+1, t+2}
    as_ring = max(relax.tau_max, 1)        # async: delay < tau_max

    def fmat(m):                           # bool (p,p) -> f32 for the MXU
        return m.astype(jnp.float32)

    def run(x0, alpha, key, per_step, per_run, knobs, data):
        del data

        def step(carry, xs):
            if has_pre:
                t, step_s, draw = xs
                grads_at = lambda views: problem.batch_grads_at(views, draw)
            else:
                t, step_s = xs
                carry["key"], sub = jax.random.split(carry["key"])
                grads_at = lambda views: problem.batch_grads(views, sub)
            x, v, alive = carry["x"], carry["v"], carry["alive"]
            scale = carry["alpha"] / p

            if kind == "adversarial":
                views = x[None] + carry["alpha"] * knobs["B_adv"] * \
                    carry["adv_dir"][None]
                g = grads_at(jnp.broadcast_to(views, (p, d)))
                x = x - scale * jnp.sum(g, 0)
                v = jnp.broadcast_to(x[None], (p, d))

            elif kind == "sync":
                g = grads_at(v)
                upd = scale * jnp.sum(g, 0)
                x = x - upd
                v = v - upd[None]

            elif kind in ("crash", "crash_subst"):
                g = grads_at(v)
                crashing = alive & (carry["crash_step"] == t)
                new_alive = alive & ~crashing
                # recv[i, j]: does i receive j's broadcast this step?
                base = alive[:, None] & alive[None, :]
                heard = (carry["hear_u"].T < 0.5) & new_alive[:, None] & ~eye
                recv = jnp.where(crashing[None, :], heard, base)
                in_recv = jnp.any(recv, axis=0)           # heard by >= 1 node
                x = x - scale * (fmat(in_recv) @ g)
                got = fmat(recv) @ g
                if kind == "crash_subst":
                    missed = jnp.sum((~recv) & in_recv[None, :], axis=1)
                    got = got + missed.astype(jnp.float32)[:, None] * g
                v = jnp.where(new_alive[:, None], v - scale * got, v)
                alive = new_alive

            elif kind == "omission":
                g = grads_at(v)
                ring, cnt = carry["ring"], carry["cnt"]
                cand = (step_s["drop_u"] < knobs["drop_prob"]) & ~eye
                # first-come quota: at most f messages outstanding, row-major
                # (i, j) order — identical to the oracle's loop order
                cf = cand.reshape(-1)
                before = jnp.cumsum(cf) - cf
                take = (cf & (before < relax.f - jnp.sum(cnt))).reshape(p, p)
                gsum = jnp.sum(g, 0)
                x = x - scale * gsum
                v = v - scale * (gsum[None] - fmat(take) @ g)
                for e in (0, 1):                          # extra delay in {0, 1}
                    m = take & (step_s["extra_delay"] == e)
                    slot = (t + 1 + e) % om_ring
                    ring = DLV.ring_deposit(ring, slot, scale * (fmat(m) @ g))
                    cnt = DLV.ring_deposit(cnt, slot, jnp.sum(m))
                delivered, ring = DLV.ring_take(ring, t % om_ring)
                v = v - delivered
                _, cnt = DLV.ring_take(cnt, t % om_ring)
                carry["ring"], carry["cnt"] = ring, cnt

            elif kind == "async":
                g = grads_at(v)
                # one-hot per-delay delivery masks; level 0 is immediate
                masks = DLV.delay_masks(step_s["delays"],
                                        max(relax.tau_max, 1))
                x = x - scale * jnp.sum(g, 0)
                v = v - scale * (masks[0] @ g)
                if as_ring > 1:
                    ring = carry["ring"]
                    for dl in range(1, relax.tau_max):
                        ring = DLV.ring_deposit(ring, (t + dl) % as_ring,
                                                scale * (masks[dl] @ g))
                    delivered, ring = DLV.ring_take(ring, t % as_ring)
                    v = v - delivered
                    carry["ring"] = ring

            elif kind == "ef_comp":
                g = grads_at(v)
                payloads, carry["err"] = C.ef_compress_rows(
                    relax.compressor, carry["alpha"] * g, carry["err"],
                    interpret=_interpret())
                x = x - scale * jnp.sum(g, 0)
                v = v - jnp.sum(payloads, 0)[None] / p

            elif kind == "elastic_norm":
                g = grads_at(v)
                perm = step_s["perm"]                     # (p, p) arrival order
                norms = jnp.sqrt(jnp.sum(g * g, axis=1))
                self_m = perm == jnp.arange(p)[:, None]
                contrib = jnp.where(self_m, 0.0, norms[perm])
                acc_before = jnp.cumsum(contrib, axis=1) - contrib
                inc = (acc_before < knobs["beta"] * norms[:, None]) | self_m
                recv = jnp.zeros((p, p), bool).at[
                    jnp.arange(p)[:, None], perm].set(inc)
                gsum = jnp.sum(g, 0)
                recvg = fmat(recv) @ g
                x = x - scale * gsum
                v = v - scale * recvg - carry["defer"]
                carry["defer"] = scale * (gsum[None] - recvg)

            elif kind == "elastic_variance":
                g = grads_at(v)
                drop = (step_s["drop_u"] < knobs["drop_prob"]) & ~eye
                nd = jnp.sum(drop, axis=1).astype(jnp.float32)[:, None]
                gsum = jnp.sum(g, 0)
                dropg = fmat(drop) @ g
                # keep@g = gsum - g - drop@g, so upd = gsum + nd*g - drop@g
                x = x - scale * gsum
                v = v - scale * (gsum[None] + nd * g - dropg) - carry["defer"]
                carry["defer"] = scale * (dropg - nd * g)

            else:
                raise ValueError(kind)

            carry["x"], carry["v"], carry["alive"] = x, v, alive
            sq = jnp.sum((x[None] - v) ** 2, axis=1)
            gap2 = jnp.max(jnp.where(alive, sq, -jnp.inf))
            return carry, (x, gap2)

        x0 = x0.astype(jnp.float32)
        carry = {"x": x0, "v": jnp.tile(x0, (p, 1)),
                 "alive": jnp.ones(p, bool), "alpha": alpha}
        xs_in = (jnp.arange(T), per_step)
        if has_pre:
            xs_in = xs_in + (problem.presample_grads(key, T, p),)
        else:
            carry["key"] = key
        if kind.startswith("crash"):
            carry["crash_step"] = per_run["crash_step"]
            carry["hear_u"] = per_run["hear_u"]
        if kind == "adversarial":
            carry["adv_dir"] = per_run["adv_dir"]
        if kind == "omission":
            carry["ring"] = DLV.ring_init(om_ring, (p, d))
            carry["cnt"] = DLV.ring_init(om_ring, (), jnp.int32)
        if kind == "async" and as_ring > 1:
            carry["ring"] = DLV.ring_init(as_ring, (p, d))
        if kind == "ef_comp":
            carry["err"] = jnp.zeros((p, d), jnp.float32)
        if kind in ("elastic_norm", "elastic_variance"):
            carry["defer"] = jnp.zeros((p, d), jnp.float32)
        _, (xs, gaps2) = jax.lax.scan(step, carry, xs_in)
        return xs, gaps2 / (alpha * alpha)

    return run


def _build_fused_run(problem, relax: Relaxation, p: int, T: int):
    """Fused fast path (`repro.kernels.sim_step`): delivery tensors for the
    whole run are precomputed in one vectorized pass, and the scan step is
    one fused kernel call — step-for-step equivalent to the unfused oracle
    step up to fp32 reduction order."""
    kind = relax.kind
    d = problem.dim
    has_defer = kind == "elastic_variance"

    def run(x0, alpha, key, per_step, per_run, knobs, data):
        x0 = x0.astype(jnp.float32)
        a, x_star = data["A"], data["x_star"]
        scale = alpha / p
        draws = problem.presample_from_data(data, key, T, p)

        if kind == "sync":
            # every view equals x exactly: the p-view gradient stack
            # collapses to one matvec + the worker-summed noise row
            nsc = scale * jnp.sum(draws, axis=1)          # (T, d)

            def step(x, n):
                x = SSK.fused_sync_step(x, a, x_star, n, alpha)
                return x, x

            _, xs = jax.lax.scan(step, x0, nsc)
            return xs, jnp.zeros(T, jnp.float32)

        u, new_alive = DLV.delivery_tensors(kind, p, T, per_step, per_run,
                                            knobs)
        u = scale * u

        def step(carry, xs_in):
            u_t, n_t, na = xs_in
            if has_defer:
                x, v, defer = SSK.fused_delivery_step(
                    carry["v"], carry["x"], a, x_star, n_t, u_t,
                    carry["defer"])
                carry = {"x": x, "v": v, "defer": defer}
            else:
                x, v = SSK.fused_delivery_step(
                    carry["v"], carry["x"], a, x_star, n_t, u_t)
                carry = {"x": x, "v": v}
            sq = jnp.sum((x[None] - v) ** 2, axis=1)
            gap2 = jnp.max(jnp.where(na, sq, -jnp.inf))
            return carry, (x, gap2)

        carry = {"x": x0, "v": jnp.tile(x0, (p, 1))}
        if has_defer:
            carry["defer"] = jnp.zeros((p, d), jnp.float32)
        if new_alive is None:
            new_alive = jnp.ones((T, p), bool)
        _, (xs, gaps2) = jax.lax.scan(step, carry, (u, draws, new_alive))
        return xs, gaps2 / (alpha * alpha)

    return run


def _build_shared_run(problem, p: int, T: int, tau_max: int):
    d = problem.dim
    has_pre = hasattr(problem, "presample_grads")

    def step(carry, xs):
        if has_pre:
            t, taus, draw = xs
            grads_at = lambda views: problem.batch_grads_at(views, draw)
        else:
            t, taus = xs
            carry["key"], sub = jax.random.split(carry["key"])
            grads_at = lambda views: problem.batch_grads(views, sub)
        x, hist, alpha = carry["x"], carry["hist"], carry["alpha"]
        idx = (t - taus) % (tau_max + 1)
        view = hist[idx, jnp.arange(d)]
        g = grads_at(view[None])[0]
        gap2 = jnp.sum((x - view) ** 2)
        x = x - alpha * g
        carry["x"] = x
        carry["hist"] = hist.at[(t + 1) % (tau_max + 1)].set(x)
        return carry, (x, gap2)

    def run(x0, alpha, key, per_step, per_run):
        del per_run
        x0 = x0.astype(jnp.float32)
        carry = {"x": x0, "hist": jnp.tile(x0, (tau_max + 1, 1)),
                 "alpha": alpha}
        xs_in = (jnp.arange(T), per_step["taus"])
        if has_pre:
            xs_in = xs_in + (problem.presample_grads(key, T, 1),)
        else:
            carry["key"] = key
        _, (xs, gaps2) = jax.lax.scan(step, carry, xs_in)
        return xs, gaps2 / (alpha * alpha)

    return run


# ---------------------------------------------------------------------------
# compiled-program cache + result assembly
# ---------------------------------------------------------------------------

def _get_run(problem, key_tup, builder, in_axes=None, outer_axes=None):
    """jit (and optionally vmap, optionally twice) one run builder, cached
    on the problem object.  ``in_axes`` batches cases; ``outer_axes`` adds a
    second level over stacked problem instances (`simulate_grid`)."""
    cache = _cache(problem)
    ck = key_tup + (in_axes, outer_axes)
    if ck not in cache:
        run = builder()
        if in_axes is not None:
            run = jax.vmap(run, in_axes=in_axes)
        if outer_axes is not None:
            run = jax.vmap(run, in_axes=outer_axes)
        cache[ck] = jax.jit(run)
    return cache[ck]


def _get_eval(problem, with_data: bool = False):
    cache = _cache(problem)
    name = "eval_data" if with_data else "eval"
    if name not in cache:
        if with_data:
            loss_d = type(problem).loss_from_data
            grad_d = type(problem).grad_from_data

            def ev(xs_rec, data):
                losses = jax.vmap(lambda xx: loss_d(data, xx))(xs_rec)
                gns = jax.vmap(
                    lambda xx: jnp.sum(grad_d(data, xx) ** 2))(xs_rec)
                return losses, gns
        else:
            def ev(xs_rec, data):
                del data
                losses = jax.vmap(problem.loss)(xs_rec)
                gns = jax.vmap(
                    lambda xx: jnp.sum(problem.grad(xx) ** 2))(xs_rec)
                return losses, gns
        cache[name] = jax.jit(ev)
    return cache[name]


def _finalize(problem, xs, gaps2, alpha, record_every) -> SimResult:
    xs_rec = xs[::record_every]
    losses, gns = _get_eval(problem)(xs_rec, None)
    return SimResult(np.asarray(losses), np.asarray(gns),
                     np.asarray(gaps2, np.float64), np.asarray(xs[-1]),
                     record_every, alpha)


def _finalize_batch(problem, xs, gaps2, alphas, record_every,
                    data=None) -> list:
    """Batched finalize: ONE loss/grad eval + bulk transfer for all runs
    (xs (B, T, d)), instead of B sequential dispatches and device syncs.
    ``alphas`` is a scalar (shared) or one alpha per run."""
    n, t, d = xs.shape
    xs_rec = xs[:, ::record_every]
    n_rec = xs_rec.shape[1]
    losses, gns = _get_eval(problem, data is not None)(
        xs_rec.reshape(n * n_rec, d), data)
    losses = np.asarray(losses).reshape(n, n_rec)
    gns = np.asarray(gns).reshape(n, n_rec)
    gaps2 = np.asarray(gaps2, np.float64)
    x_fin = np.asarray(xs[:, -1])
    if np.ndim(alphas) == 0:
        alphas = [alphas] * n
    return [SimResult(losses[i], gns[i], gaps2[i], x_fin[i],
                      record_every, float(alphas[i])) for i in range(n)]


def _as_device(schedule: Schedule):
    to_j = lambda tree: jax.tree.map(jnp.asarray, tree)
    return to_j(schedule.per_step), to_j(schedule.per_run)


def _stack_schedules(scheds) -> tuple:
    per_step = jax.tree.map(lambda *a: jnp.asarray(np.stack(a)),
                            *[s.per_step for s in scheds])
    per_run = jax.tree.map(lambda *a: jnp.asarray(np.stack(a)),
                           *[s.per_run for s in scheds])
    return per_step, per_run


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def simulate_scan(problem, relax: Relaxation, p: int, alpha: float, T: int,
                  seed: int = 0, x0=None, record_every: int = 10,
                  schedule: Optional[Schedule] = None,
                  fused="auto") -> SimResult:
    """Compiled equivalent of :func:`repro.core.sim_ref.simulate_ref`."""
    if schedule is None:
        schedule = make_schedule(relax, p, problem.dim, T, seed)
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    use_fused = _resolve_fused(problem, relax, fused)
    run = _get_run(problem, (_static_key(relax), p, T, use_fused),
                   lambda: _build_run(problem, relax, p, T, use_fused))
    per_step, per_run = _as_device(schedule)
    data = problem.sim_data() if use_fused else None
    xs, gaps2 = run(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                    jax.random.PRNGKey(seed + 1), per_step, per_run,
                    _knob_values(relax), data)
    return _finalize(problem, xs, gaps2, alpha, record_every)


_SWEEP_AXES = (None, None, 0, 0, 0, None, None)
_CASE_AXES = (None, 0, 0, 0, 0, 0, None)
_PROBLEM_AXES = (None, None, None, None, None, None, 0)


def simulate_sweep(problem, relax: Relaxation, p: int, alpha: float, T: int,
                   seeds, x0=None, record_every: int = 10,
                   fused="auto") -> list:
    """vmap one compiled run over seeds: schedules and gradient keys get a
    leading seed axis; x0/alpha are broadcast. Returns [SimResult] per seed.
    """
    seeds = list(seeds)
    scheds = [make_schedule(relax, p, problem.dim, T, s) for s in seeds]
    per_step, per_run = _stack_schedules(scheds)
    keys = jnp.stack([jax.random.PRNGKey(s + 1) for s in seeds])
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    use_fused = _resolve_fused(problem, relax, fused)
    vrun = _get_run(problem, (_static_key(relax), p, T, use_fused),
                    lambda: _build_run(problem, relax, p, T, use_fused),
                    in_axes=_SWEEP_AXES)
    data = problem.sim_data() if use_fused else None
    xs, gaps2 = vrun(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                     keys, per_step, per_run, _knob_values(relax), data)
    return _finalize_batch(problem, xs, gaps2, alpha, record_every,
                           data=data)


@dataclass
class GridResult:
    """Results of :func:`simulate_grid`, keyed by
    ``(i_problem, i_relax, p, i_alpha, seed)``."""

    results: dict = field(default_factory=dict)

    def __getitem__(self, key) -> SimResult:
        return self.results[key]

    def __len__(self) -> int:
        return len(self.results)

    def select(self, i_problem=None, i_relax=None, p=None, i_alpha=None,
               seed=None) -> list:
        """All results matching the given coordinates, key-sorted."""
        want = (i_problem, i_relax, p, i_alpha, seed)
        return [r for k, r in sorted(self.results.items())
                if all(w is None or kk == w for kk, w in zip(k, want))]


def simulate_grid(problems, relaxations, p_list, alphas, T: int,
                  seeds=(0,), x0=None, record_every: int = 10,
                  fused="auto", schedule_fn=None) -> GridResult:
    """Batched multi-(p, d) sweeps: one compiled program per
    (relaxation-statics, p) group instead of a Python loop of
    ``simulate_sweep`` calls.

    ``schedule_fn(i_relax, p, seed) -> Schedule | None`` overrides the
    pre-drawn scheduling randomness per case (None falls back to
    :func:`make_schedule`).  This is the co-simulation hook: measured
    ``tau(t, worker)`` traces from `repro.cluster`'s event loop enter the
    grid here instead of the oblivious-adversary draw.  Schedules within
    one (relaxation-statics, p) group stack on the vmap axis, so an
    override must keep the same array shapes as the default draw.

    The cartesian product problems x relaxations x alphas x seeds is run
    for every p in ``p_list``.  Within a group, cases (schedule, alpha,
    float knobs, gradient key) stack on a vmap axis; when the group is
    fused and several same-shape problem instances are given, their
    ``sim_data`` pytrees stack on a SECOND vmap axis (A becomes (B, d, d))
    — the whole grid is then a single XLA program.  Relaxations in one
    group may differ only in float knobs (drop_prob/beta/B_adv); kinds or
    integer bounds that differ compile separate groups, transparently.

    Unfused groups with several problems fall back to one program per
    problem (the oracle step closes over the problem object).  Every
    (kind, seed, p, T) trajectory is identical to ``simulate_scan``'s.
    """
    problems = problems if isinstance(problems, (list, tuple)) \
        else [problems]
    relaxations = relaxations if isinstance(relaxations, (list, tuple)) \
        else [relaxations]
    p_list = [p_list] if isinstance(p_list, int) else list(p_list)
    alphas = [alphas] if isinstance(alphas, (int, float)) else list(alphas)
    seeds = [seeds] if isinstance(seeds, int) else list(seeds)
    d = problems[0].dim
    if any(pr.dim != d for pr in problems):
        raise ValueError("simulate_grid problems must share dim")
    if x0 is None:
        x0 = np.zeros(d, np.float32)
    x0j = jnp.asarray(x0, jnp.float32)

    grid = GridResult()
    groups: dict = {}
    for ir, r in enumerate(relaxations):
        groups.setdefault(_static_key(r), []).append(ir)

    for p in p_list:
        for skey, irs in groups.items():
            relax0 = relaxations[irs[0]]
            use_fused = _resolve_fused(problems[0], relax0, fused) and all(
                SSK.supports_fused(pr, relax0) for pr in problems)
            if fused is True and not use_fused:
                raise ValueError(
                    "fused=True but not every problem in the grid supports "
                    f"the fused path for kind={relax0.kind!r}")
            cases = [(ir, ia, s) for ir in irs
                     for ia in range(len(alphas)) for s in seeds]
            scheds = [schedule_fn(ir, p, s) if schedule_fn else None
                      for ir, _, s in cases]
            scheds = [sc if sc is not None
                      else make_schedule(relaxations[ir], p, d, T, s)
                      for sc, (ir, _, s) in zip(scheds, cases)]
            per_step, per_run = _stack_schedules(scheds)
            alph = jnp.asarray([alphas[ia] for _, ia, _ in cases],
                               jnp.float32)
            keys = jnp.stack([jax.random.PRNGKey(s + 1)
                              for _, _, s in cases])
            knobs = jax.tree.map(
                lambda *a: jnp.stack(a),
                *[_knob_values(relaxations[ir]) for ir, _, _ in cases])
            alphas_per_case = [alphas[ia] for _, ia, _ in cases]

            if use_fused:
                multi = len(problems) > 1
                data = jax.tree.map(
                    lambda *a: jnp.stack(a),
                    *[pr.sim_data() for pr in problems]) if multi \
                    else problems[0].sim_data()
                vrun = _get_run(
                    problems[0], ("grid", skey, p, T, True, multi),
                    lambda: _build_run(problems[0], relax0, p, T, True),
                    in_axes=_CASE_AXES,
                    outer_axes=_PROBLEM_AXES if multi else None)
                xs, gaps2 = vrun(x0j, alph, keys, per_step, per_run, knobs,
                                 data)
                if not multi:
                    xs, gaps2 = xs[None], gaps2[None]
                for ip, prob in enumerate(problems):
                    res = _finalize_batch(prob, xs[ip], gaps2[ip],
                                          alphas_per_case, record_every,
                                          data=prob.sim_data())
                    for (ir, ia, s), r in zip(cases, res):
                        grid.results[(ip, ir, p, ia, s)] = r
            else:
                for ip, prob in enumerate(problems):
                    vrun = _get_run(
                        prob, ("grid", skey, p, T, False),
                        lambda: _build_run(prob, relax0, p, T, False),
                        in_axes=_CASE_AXES)
                    xs, gaps2 = vrun(x0j, alph, keys, per_step, per_run,
                                     knobs, None)
                    res = _finalize_batch(prob, xs, gaps2, alphas_per_case,
                                          record_every)
                    for (ir, ia, s), r in zip(cases, res):
                        grid.results[(ip, ir, p, ia, s)] = r
    return grid


def simulate_shared_memory_scan(problem, p: int, alpha: float, T: int,
                                tau_max: int, seed: int = 0, x0=None,
                                record_every: int = 10,
                                schedule: Optional[Schedule] = None
                                ) -> SimResult:
    if schedule is None:
        schedule = make_shared_memory_schedule(p, problem.dim, T, tau_max,
                                               seed)
    if x0 is None:
        x0 = np.zeros(problem.dim, np.float32)
    run = _get_run(problem, ("shm", p, T, tau_max),
                   lambda: _build_shared_run(problem, p, T, tau_max))
    per_step, per_run = _as_device(schedule)
    xs, gaps2 = run(jnp.asarray(x0, jnp.float32), jnp.float32(alpha),
                    jax.random.PRNGKey(seed + 1), per_step, per_run)
    return _finalize(problem, xs, gaps2, alpha, record_every)
