"""Lossy gradient compression operators (paper §4.1(d), Appendix B.7).

Every operator ``Q`` satisfies the contraction property (Eq. 25):

    ||Q(w) - w||^2 <= gamma * ||w||^2,   0 <= gamma < 1

which is what the elastic-consistency bound for error-feedback methods needs
(Lemma 18: B = sqrt((2-gamma)*gamma/(1-gamma)^3) * M). The ``gamma_bound``
attributes give the per-operator worst-case gamma used by the theory checks.

``ef_compress`` implements one error-feedback round of Algorithm 6:
w = eps + u;  payload = Q(w);  eps' = w - Q(w).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Top-K sparsification (Strom'15 / Aji-Heafield'17 style)
# ---------------------------------------------------------------------------

def topk_compress(w: jax.Array, k: int):
    """Magnitude top-k of a flat vector. Returns (values, indices)."""
    flat = w.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values, idx, n: int):
    return jnp.zeros((n,), values.dtype).at[idx].set(values)


def topk_q(w: jax.Array, k: int) -> jax.Array:
    """Dense Q(w) for theory checks."""
    vals, idx = topk_compress(w, k)
    return topk_decompress(vals, idx, w.size).reshape(w.shape)


def topk_gamma(n: int, k: int) -> float:
    """TopK satisfies (25) with gamma = (n-k)/n."""
    return (n - k) / n


# ---------------------------------------------------------------------------
# One-bit quantization (Seide et al.'14, Eq. 30)
# ---------------------------------------------------------------------------

def onebit_q(w: jax.Array) -> jax.Array:
    """[Q(w)]_i = mean of w over the sign class of i."""
    flat = w.reshape(-1).astype(jnp.float32)
    pos = flat >= 0
    n_pos = jnp.maximum(jnp.sum(pos), 1)
    n_neg = jnp.maximum(jnp.sum(~pos), 1)
    mean_pos = jnp.sum(jnp.where(pos, flat, 0.0)) / n_pos
    mean_neg = jnp.sum(jnp.where(~pos, flat, 0.0)) / n_neg
    return jnp.where(pos, mean_pos, mean_neg).reshape(w.shape).astype(w.dtype)


def onebit_compress(w: jax.Array):
    """Wire format: (sign bitmap packed into uint8, mean_pos, mean_neg)."""
    flat = w.reshape(-1)
    pos = (flat >= 0)
    pad = (-flat.size) % 8
    bits = jnp.pad(pos, (0, pad)).reshape(-1, 8)
    packed = jnp.sum(bits.astype(jnp.uint8)
                     * (2 ** jnp.arange(8, dtype=jnp.uint8)), axis=-1,
                     dtype=jnp.uint8)
    n_pos = jnp.maximum(jnp.sum(pos), 1)
    n_neg = jnp.maximum(jnp.sum(~pos), 1)
    flat32 = flat.astype(jnp.float32)
    mean_pos = jnp.sum(jnp.where(pos, flat32, 0.0)) / n_pos
    mean_neg = jnp.sum(jnp.where(~pos, flat32, 0.0)) / n_neg
    return packed, mean_pos, mean_neg


def onebit_decompress(packed, mean_pos, mean_neg, n: int, dtype=jnp.float32):
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    pos = bits.reshape(-1)[:n].astype(bool)
    return jnp.where(pos, mean_pos, mean_neg).astype(dtype)


def onebit_gamma(n: int) -> float:
    """One-bit quantization satisfies (25) with gamma = 1 - 1/d in the worst
    case (paper App. B.7)."""
    return 1.0 - 1.0 / n


# ---------------------------------------------------------------------------
# QSGD-style unbiased random quantization (Alistarh et al.'17)
# ---------------------------------------------------------------------------

def qsgd_q(w: jax.Array, key: jax.Array, levels: int = 4) -> jax.Array:
    """Stochastic uniform quantization to ``levels`` levels of |w|/||w||.
    Unbiased: E[Q(w)] = w."""
    flat = w.reshape(-1).astype(jnp.float32)
    norm = jnp.linalg.norm(flat) + 1e-30
    scaled = jnp.abs(flat) / norm * levels
    lower = jnp.floor(scaled)
    prob = scaled - lower
    rnd = jax.random.uniform(key, flat.shape)
    q = (lower + (rnd < prob)) / levels
    return (jnp.sign(flat) * q * norm).reshape(w.shape).astype(w.dtype)


# ---------------------------------------------------------------------------
# Error feedback (Algorithm 6)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Compressor:
    """Dense-form compressor with its contraction constant.

    ``kind``/``ratio`` let batched callers (the scan simulator engine) route
    the row-wise EF round through the fused Pallas kernels instead of the
    dense ``q``; ``kind="custom"`` always takes the dense path.
    """

    q: Callable[[jax.Array], jax.Array]
    gamma: Callable[[int], float]
    name: str
    kind: str = "custom"          # topk | onebit | custom
    ratio: float = 0.0            # topk only


def topk_compressor(ratio: float) -> Compressor:
    def q(w):
        k = max(1, int(round(w.size * ratio)))
        return topk_q(w, k)

    return Compressor(q, lambda n: topk_gamma(n, max(1, int(round(n * ratio)))),
                      f"topk{ratio}", kind="topk", ratio=ratio)


def onebit_compressor() -> Compressor:
    return Compressor(onebit_q, onebit_gamma, "onebit", kind="onebit")


def ef_compress(comp: Compressor, update: jax.Array, err: jax.Array):
    """One error-feedback round (Alg 6 lines 2-4).

    update: alpha * gradient;  err: accumulated residual.
    Returns (payload Q(w), new_err)."""
    w = err + update
    payload = comp.q(w)
    return payload, w - payload


def ef_compress_rows(comp: Compressor, updates: jax.Array, errs: jax.Array,
                     use_kernel: bool = True, interpret: bool = True):
    """Batched error-feedback round: one row per worker.

    updates/errs: (p, d) — each row is an independent Alg-6 round. For the
    topk/onebit compressors the whole batch runs through the fused Pallas
    EF kernels (interpret mode on CPU; row-local selection == per-worker
    global selection since each worker is one row). Returns
    (payloads (p, d), new_errs (p, d)) with payload = Q(w), w = err + upd.
    """
    w = errs + updates.astype(jnp.float32)
    p, d = w.shape
    if use_kernel and comp.kind == "topk":
        from repro.kernels.topk_ef.ops import compress_leaf
        _, _, new_errs = compress_leaf(updates.astype(jnp.float32), errs,
                                       ratio=comp.ratio, interpret=interpret)
        return w - new_errs, new_errs
    if use_kernel and comp.kind == "onebit" and d % 8 == 0:
        from repro.kernels.onebit_ef.ops import compress_leaf
        _, _, new_errs = compress_leaf(updates.astype(jnp.float32), errs,
                                       interpret=interpret)
        return w - new_errs, new_errs
    payloads = jax.vmap(comp.q)(w)
    return payloads, w - payloads
