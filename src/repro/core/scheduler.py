"""Production gradient-synchronization strategies (the paper's technique as
a first-class feature of the sharded trainer).

These functions run *inside* a ``shard_map`` over the data-parallel mesh axes
(``data``, and ``pod`` for multi-pod): each shard holds its local gradient
pytree and the strategy decides what crosses the wire.

Strategies
----------
exact      : ``pmean`` — the perfectly-consistent baseline (BytePS semantics).
topk_ef    : per-shard magnitude top-k + error feedback (Alg 6). The wire
             payload is (values, indices) all-gathered over the data axes —
             with ratio r the collective moves ~2*r*p*n words instead of the
             ~2n of a ring all-reduce.
onebit_ef  : sign/mean 1-bit quantization + EF (Eq. 30); wire payload is a
             packed bitmap + two means per row.
elastic    : the TPU/SPMD adaptation of §5's elastic scheduler — per-step
             *partial* synchronization over layer buckets with local residual
             accumulation and retroactive correction (deferred mass is synced
             on the bucket's next turn). The realized elastic-consistency gap
             ||x_t - v_t||^2/alpha^2 = ||mean deferred residual||^2 is
             tracked on-device and a `budget` forces full sync when exceeded
             (Def. 1 as a runtime knob).

Compression is applied along dims *not* sharded by the ``model`` axis so each
device compresses only local data (no tensor-parallel collectives sneak in);
the param PartitionSpecs drive that choice.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class SyncConfig:
    strategy: str = "exact"       # exact | topk_ef | onebit_ef | elastic
    axis_names: tuple = ("data",)
    wire_dtype: str = "f32"       # f32 | bf16: dtype crossing the data axes
    #                               (bf16 halves collective bytes; a
    #                               beyond-paper lever, composes with EF)
    # compression
    topk_ratio: float = 1.0 / 64.0
    # elastic scheduling
    n_buckets: int = 8
    beta: float = 0.9             # norm gate: sync buckets covering beta of norm
    gate: str = "norm"            # norm | static
    phase_period: int = 4         # static gate: bucket b syncs when
    #                               step % period == b % period
    budget_b: float = 0.0         # elastic-consistency budget (0 = off):
    #                               force full sync when gap exceeds it
    track_gap: bool = True        # gap2_over_alpha2 metric: for the
    #                               compressed strategies it costs a FULL
    #                               WIDTH pmean of the EF residuals (found
    #                               by repro.analysis's collective
    #                               inventory) — turn it off to keep the
    #                               wire at the compressed payload only
    #                               (the metric then reports 0).  The
    #                               elastic norm gate still computes the
    #                               gap it *needs* (budget enforcement)
    #                               regardless.


def _pmean(x, axes):
    return jax.lax.pmean(x, axis_name=axes)


def _axis_size(axes):
    return jax.lax.psum(1, axis_name=axes)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def init_sync_state(cfg: SyncConfig, grads_like):
    zeros = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    if cfg.strategy == "exact":
        return {"step": jnp.zeros((), jnp.int32)}
    if cfg.strategy in ("topk_ef", "onebit_ef"):
        return {"err": zeros, "step": jnp.zeros((), jnp.int32)}
    if cfg.strategy == "elastic":
        return {"residual": zeros, "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.strategy)


# ---------------------------------------------------------------------------
# per-leaf compression along non-model dims
# ---------------------------------------------------------------------------

def _split_model_dims(spec, ndim: int):
    spec = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    model = [i for i, s in enumerate(spec) if s is not None]
    other = [i for i in range(ndim) if i not in model]
    return model, other


def _to_rows(g, spec):
    """Reshape leaf to (M, R): M = product of sharded dims (kept local),
    R = the rest (compressed)."""
    model, other = _split_model_dims(spec, g.ndim)
    perm = model + other
    gt = jnp.transpose(g, perm)
    m = 1
    for i in model:
        m *= g.shape[i]
    return gt.reshape(m, -1), perm, gt.shape


def _from_rows(rows, perm, tshape):
    gt = rows.reshape(tshape)
    inv = [0] * len(perm)
    for i, p_ in enumerate(perm):
        inv[p_] = i
    return jnp.transpose(gt, inv)


def _topk_rows(rows, ratio):
    """Row-wise magnitude top-k of (M, R) rows -> (signed values, indices),
    k = max(1, round(R * ratio))."""
    r = rows.shape[1]
    k = max(1, int(round(r * ratio)))
    _, idx = jax.lax.top_k(jnp.abs(rows), k)
    return jnp.take_along_axis(rows, idx, axis=1), idx


def _onebit_rows(rows):
    """Row-wise sign/mean 1-bit stats of (M, R) rows (Eq. 30):
    -> (pos mask (M, R), mean_pos (M,), mean_neg (M,))."""
    r = rows.shape[1]
    pos = rows >= 0
    n_pos = jnp.maximum(jnp.sum(pos, axis=1), 1)
    n_neg = jnp.maximum(r - jnp.sum(pos, axis=1), 1)
    mean_pos = jnp.sum(jnp.where(pos, rows, 0.0), axis=1) / n_pos
    mean_neg = jnp.sum(jnp.where(pos, 0.0, rows), axis=1) / n_neg
    return pos, mean_pos, mean_neg


def ef_compress_leaf(g, err, spec, method: str, topk_ratio: float = 1 / 64):
    """One *local* compression round of a leaf (no collective): returns
    ``(payload, new_err)`` where ``payload`` is the densified compressed
    gradient Q(err + g) — what a worker would put on the wire — and
    ``new_err = (err + g) - payload`` is the error-feedback residual.

    Shared by the sync strategies below and by the bounded-staleness engine
    (`repro.dist.async_engine`), which buffers payloads in per-worker delay
    rings instead of synchronizing them immediately.  Pass a zero ``err``
    and discard ``new_err`` for compression *without* error feedback.
    """
    w = err + g.astype(jnp.float32)
    if w.size == 0:  # zero-layer dry-run variants
        return w, w
    rows, perm, tshape = _to_rows(w, spec)
    m = rows.shape[0]
    if method == "topk":
        vals, idx = _topk_rows(rows, topk_ratio)
        q = jnp.zeros_like(rows).at[
            jnp.arange(m)[:, None], idx].add(vals)
    elif method == "onebit":
        pos, mean_pos, mean_neg = _onebit_rows(rows)
        q = jnp.where(pos, mean_pos[:, None], mean_neg[:, None])
    else:
        raise ValueError(f"unknown compressor {method!r}")
    payload = _from_rows(q, perm, tshape)
    return payload, w - payload


def leaf_rows_geometry(shape, spec):
    """Static row-space geometry of a leaf: ``(m, r, perm, tshape)`` for
    the (M, R) layout :func:`_to_rows` produces — M = product of
    model-sharded dims (kept local), R = the rest (compressed).  Lets the
    bounded-staleness engine size compact payload buffers without tracing
    a compression."""
    model, other = _split_model_dims(spec, len(shape))
    perm = model + other
    tshape = tuple(shape[i] for i in perm)
    m = 1
    for i in model:
        m *= shape[i]
    size = 1
    for s in shape:
        size *= s
    r = size // m if m else 0
    return m, r, perm, tshape


def ef_compress_leaf_compact(g, err, spec, method: str,
                             topk_ratio: float = 1 / 64, impl: str = "auto"):
    """One local compression round of a leaf, kept in *wire form*: the
    fused-reduction twin of :func:`ef_compress_leaf`.

    Returns ``(payload, new_err)`` where ``payload`` is a dict of compact
    row-space arrays — ``{"vals" (M, k), "idx" (M, k)}`` for top-k,
    ``{"pos" (M, R) bool, "means" (M, 2)}`` for one-bit — and ``new_err``
    the error-feedback residual in the leaf's own shape.  Q(err + g) is
    never densified: the consumer (`repro.dist.async_engine`) all-gathers
    the compact payload and reduces it with the `kernels.cr_reduce`
    compress-then-reduce family.  The densified reconstruction
    (scatter / sign-select of the payload) is bit-identical to
    :func:`ef_compress_leaf`'s payload, which is what makes the fused and
    densified engines trajectory-equal.

    Zero-size leaves return zero-size payload arrays (``k`` collapses to
    0) so the payload tree keeps a uniform structure.
    """
    from repro.kernels.cr_reduce import ops as CR
    w = err + g.astype(jnp.float32)
    m, r, perm, tshape = leaf_rows_geometry(g.shape, spec)
    if w.size == 0:  # zero-layer dry-run variants
        if method == "topk":
            payload = {"vals": jnp.zeros((m, 0), jnp.float32),
                       "idx": jnp.zeros((m, 0), jnp.int32)}
        else:
            payload = {"pos": jnp.zeros((m, r), bool),
                       "means": jnp.zeros((m, 2), jnp.float32)}
        return payload, w
    rows, perm, tshape = _to_rows(w, spec)
    if method == "topk":
        vals, idx, err_rows = CR.topk_compress_rows(
            rows, jnp.zeros_like(rows), topk_ratio, impl=impl)
        payload = {"vals": vals, "idx": idx}
    elif method == "onebit":
        pos, means, err_rows = CR.onebit_compress_rows(
            rows, jnp.zeros_like(rows))
        payload = {"pos": pos, "means": means}
    else:
        raise ValueError(f"unknown compressor {method!r}")
    return payload, _from_rows(err_rows, perm, tshape)


def _leaf_topk_sync(g, err, spec, ratio, axes):
    """Top-k + EF sync of one leaf. Returns (synced_mean, new_err)."""
    w = err + g.astype(jnp.float32)
    if w.size == 0:  # zero-layer dry-run variants
        return w, w
    rows, perm, tshape = _to_rows(w, spec)
    m, r = rows.shape
    vals, idx = _topk_rows(rows, ratio)                    # signed values
    k = vals.shape[1]
    # wire: all-gather compressed payloads over the data axes
    g_vals = jax.lax.all_gather(vals.astype(jnp.bfloat16), axis_name=axes,
                                tiled=False)               # (p, M, k)
    g_idx = jax.lax.all_gather(idx.astype(jnp.int32), axis_name=axes,
                               tiled=False)
    p = g_vals.shape[0]
    g_vals = g_vals.reshape(p, m, k)
    g_idx = g_idx.reshape(p, m, k)

    # one batched scatter-add over all p payloads (duplicate (row, idx)
    # targets accumulate); loop-free so the elastic step can run inside a
    # partial-auto shard_map without a while op in the HLO
    dense = jnp.zeros((m, r), jnp.float32).at[
        jnp.arange(m)[None, :, None], g_idx].add(g_vals.astype(jnp.float32))
    synced = _from_rows(dense / p, perm, tshape)
    own_dense = jnp.zeros((m, r), jnp.float32).at[
        jnp.arange(m)[:, None], idx].add(vals.astype(jnp.float32))
    new_err = w - _from_rows(own_dense, perm, tshape)
    return synced, new_err


def _leaf_onebit_sync(g, err, spec, axes):
    """1-bit (sign/mean) + EF sync of one leaf (Eq. 30 per local row)."""
    w = err + g.astype(jnp.float32)
    if w.size == 0:  # zero-layer dry-run variants
        return w, w
    rows, perm, tshape = _to_rows(w, spec)
    m, r = rows.shape
    pos, mean_pos, mean_neg = _onebit_rows(rows)
    # wire: bool bitmap (1 byte/elt in HLO; the Pallas kernel packs 8x) +
    # two means per row
    g_pos = jax.lax.all_gather(pos, axis_name=axes)        # (p, M, R) i1
    g_mp = jax.lax.all_gather(mean_pos, axis_name=axes)
    g_mn = jax.lax.all_gather(mean_neg, axis_name=axes)
    p = g_pos.shape[0]
    g_pos = g_pos.reshape(p, m, r)
    g_mp, g_mn = g_mp.reshape(p, m), g_mn.reshape(p, m)
    dense = jnp.sum(jnp.where(g_pos, g_mp[..., None], g_mn[..., None]),
                    axis=0)
    synced = _from_rows(dense / p, perm, tshape)
    q_own = jnp.where(pos, mean_pos[:, None], mean_neg[:, None])
    new_err = w - _from_rows(q_own, perm, tshape)
    return synced, new_err


# ---------------------------------------------------------------------------
# elastic bucketing
# ---------------------------------------------------------------------------

def bucket_assignment(grads_like, n_buckets: int):
    """Assign leaves to buckets contiguously by traversal order (layer
    order), balancing by element count — the analogue of the paper's
    per-layer gradient buckets."""
    leaves = jax.tree.leaves(grads_like)
    sizes = [x.size for x in leaves]
    total = sum(sizes)
    target = total / n_buckets
    assign, b, acc = [], 0, 0.0
    for s in sizes:
        assign.append(min(b, n_buckets - 1))
        acc += s
        if acc >= target * (b + 1) and b < n_buckets - 1:
            b += 1
    return assign


def _bucket_norms(resid, assign, n_buckets):
    leaves = jax.tree.leaves(resid)
    norms = jnp.zeros((n_buckets,), jnp.float32)
    for a, leaf in zip(assign, leaves):
        norms = norms.at[a].add(jnp.sum(jnp.square(leaf)))
    return norms


def norm_gate_mask(norms: jax.Array, beta: float, budget_b2: float = 0.0,
                   gap2: Optional[jax.Array] = None) -> jax.Array:
    """Select buckets (largest first) until >= beta of total norm^2 is
    covered. If a budget is set and the realized gap exceeds it, sync all."""
    total = jnp.sum(norms)
    order = jnp.argsort(-norms)
    sorted_norms = norms[order]
    cum = jnp.cumsum(sorted_norms)
    # bucket at sorted position j is selected if the cumulative mass *before*
    # it is still < beta * total
    sel_sorted = (cum - sorted_norms) < beta * total
    mask = jnp.zeros_like(sel_sorted).at[order].set(sel_sorted)
    if budget_b2 > 0.0 and gap2 is not None:
        mask = jnp.where(gap2 > budget_b2, jnp.ones_like(mask), mask)
    return mask


def static_gate_mask(step: int, n_buckets: int, period: int):
    """Deterministic round-robin: bucket b syncs when step % period ==
    b % period. `step` must be a static python int (per-phase compilation) so
    skipped buckets emit *no* collective in the HLO."""
    return [b % period == step % period for b in range(n_buckets)]


# ---------------------------------------------------------------------------
# strategy entry point (called inside shard_map)
# ---------------------------------------------------------------------------

def sync_gradients(cfg: SyncConfig, grads, state, specs=None,
                   static_phase: Optional[int] = None):
    """Synchronize local gradients across the data axes.

    Returns (synced_grads, new_state, metrics). ``specs`` is the param
    PartitionSpec tree (required for the compressed strategies).
    """
    axes = cfg.axis_names
    step = state["step"]
    metrics = {}

    if cfg.strategy == "exact":
        wire = jnp.bfloat16 if cfg.wire_dtype == "bf16" else jnp.float32
        synced = jax.tree.map(
            lambda g: _pmean(g.astype(wire), axes).astype(jnp.float32),
            grads)
        return synced, {"step": step + 1}, {"gap2_over_alpha2": jnp.zeros(())}

    if cfg.strategy in ("topk_ef", "onebit_ef"):
        assert specs is not None, "compressed sync needs param specs"
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(state["err"])
        flat_s = treedef.flatten_up_to(specs)
        synced, errs = [], []
        for g, e, sp in zip(flat_g, flat_e, flat_s):
            if cfg.strategy == "topk_ef":
                s, ne = _leaf_topk_sync(g, e, sp, cfg.topk_ratio, axes)
            else:
                s, ne = _leaf_onebit_sync(g, e, sp, axes)
            synced.append(s)
            errs.append(ne)
        synced = jax.tree.unflatten(treedef, synced)
        new_err = jax.tree.unflatten(treedef, errs)
        if cfg.track_gap:
            # realized elastic gap: v - x = mean_i eps_i (Eq. 28) — a full-
            # width pmean per leaf, i.e. as many wire bytes as an exact sync
            mean_err = jax.tree.map(lambda e: _pmean(e, axes), new_err)
            gap2 = sum(jnp.sum(jnp.square(x))
                       for x in jax.tree.leaves(mean_err))
        else:
            gap2 = jnp.zeros(())
        metrics["gap2_over_alpha2"] = gap2
        return synced, {"err": new_err, "step": step + 1}, metrics

    if cfg.strategy == "elastic":
        assign = bucket_assignment(grads, cfg.n_buckets)
        resid = jax.tree.map(
            lambda r, g: r + g.astype(jnp.float32), state["residual"], grads)
        flat_r, treedef = jax.tree.flatten(resid)

        wire = jnp.bfloat16 if cfg.wire_dtype == "bf16" else jnp.float32

        def wmean(r):
            return _pmean(r.astype(wire), axes).astype(jnp.float32)

        if cfg.gate == "static":
            assert static_phase is not None, \
                "static gate needs a compile-time phase"
            mask_list = static_gate_mask(static_phase, cfg.n_buckets,
                                         cfg.phase_period)
            synced, new_resid = [], []
            for a, r in zip(assign, flat_r):
                if mask_list[a]:
                    synced.append(wmean(r))          # sync backlog
                    new_resid.append(jnp.zeros_like(r))
                else:
                    synced.append(jnp.zeros_like(r))  # defer (no collective)
                    new_resid.append(r)
            gap2 = (sum(jnp.sum(jnp.square(_pmean(r, axes)))
                        for r in new_resid)
                    if cfg.track_gap else jnp.zeros(()))
        else:
            norms_local = _bucket_norms(resid, assign, cfg.n_buckets)
            norms = jax.lax.psum(norms_local, axis_name=axes)
            # the budget gate NEEDS last step's realized gap — that pmean is
            # semantics, not metrics, so it ignores track_gap; without a
            # budget it is skipped entirely (no collective lowered)
            gap_prev = (sum(jnp.sum(jnp.square(_pmean(r, axes)))
                            for r in jax.tree.leaves(state["residual"]))
                        if cfg.budget_b > 0.0 else None)
            mask = norm_gate_mask(norms, cfg.beta,
                                  cfg.budget_b * cfg.budget_b, gap_prev)
            synced, new_resid = [], []
            for a, r in zip(assign, flat_r):
                m = mask[a].astype(jnp.float32)
                s = wmean(r)             # semantic path: psum always lowered
                synced.append(s * m)
                new_resid.append(r * (1.0 - m))
            gap2 = (sum(jnp.sum(jnp.square(_pmean(r, axes)))
                        for r in new_resid)
                    if cfg.track_gap else jnp.zeros(()))

        synced = jax.tree.unflatten(treedef, synced)
        new_resid = jax.tree.unflatten(treedef, new_resid)
        metrics["gap2_over_alpha2"] = gap2
        return synced, {"residual": new_resid, "step": step + 1}, metrics

    raise ValueError(cfg.strategy)
