"""Elastic consistency — the paper's contribution.

  * ``compression``  — contraction compressors Q (Eq. 25) + error feedback
  * ``theory``       — Table 1 bounds and Theorem 2-5 RHS evaluators
  * ``problems``     — strongly-convex / non-convex testbeds
  * ``sim``          — exact-semantics simulator of Algorithms 1-6
  * ``scheduler``    — production SPMD sync strategies (exact / topk_ef /
                       onebit_ef / elastic) with on-device gap tracking
"""
from repro.core.sim import Relaxation, SimResult, simulate, simulate_shared_memory  # noqa: F401
from repro.core.scheduler import SyncConfig, init_sync_state, sync_gradients  # noqa: F401
from repro.core import compression, theory, problems  # noqa: F401
