"""Theoretical elastic-consistency bounds (Table 1) and convergence-rate
right-hand sides (Theorems 2-5), used to validate measurements against the
paper's own claims.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Table 1: elastic consistency constants B
# ---------------------------------------------------------------------------

def b_shared_memory(d: int, tau_max: int, m2: float) -> float:
    """Shared-memory tau-bounded asynchrony: B = sqrt(d) * tau_max * M
    (Lemma 17)."""
    return math.sqrt(d) * tau_max * math.sqrt(m2)


def b_async_mp(p: int, tau_max: int, m2: float) -> float:
    """Message-passing tau-bounded asynchrony: B = (p-1) tau_max M / p
    (Lemma 15)."""
    return (p - 1) * tau_max * math.sqrt(m2) / p


def b_async_mp_variance(p: int, tau_max: int, sigma2: float,
                        const: float = 3.0) -> float:
    """Self-substituting asynchronous MP: B = O((p-1) tau_max sigma / p)."""
    return const * (p - 1) * tau_max * math.sqrt(sigma2) / p


def b_crash_m(p: int, f: int, m2: float) -> float:
    """Synchronous MP, f crash/message-drop faults: B = f M / p (Lemma 13/14)."""
    return f * math.sqrt(m2) / p


def b_crash_variance(p: int, f: int, sigma2: float) -> float:
    """Crash faults with self-substitution: B = 3 f sigma / p (Lemma 12)."""
    return 3.0 * f * math.sqrt(sigma2) / p


def b_ef_compression(gamma: float, m2: float) -> float:
    """EF compression: B = sqrt((2-gamma) gamma / (1-gamma)^3) * M
    (Lemma 18)."""
    return math.sqrt((2 - gamma) * gamma / (1 - gamma) ** 3 * m2)


def b_elastic_scheduler_variance(sigma2: float) -> float:
    """Variance-bounded elastic scheduler: B = 3 sigma (Lemma 16)."""
    return 3.0 * math.sqrt(sigma2)


# ---------------------------------------------------------------------------
# Theorem RHS evaluators
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ProblemConstants:
    L: float            # smoothness
    sigma2: float       # gradient variance bound
    f0_minus_fstar: float
    c: float = 0.0      # strong convexity (0 if N/A)
    x0_dist2: float = 0.0  # ||x0 - x*||^2


def thm2_rhs(pc: ProblemConstants, B: float, T: int) -> float:
    """Single-step non-convex rate bound (Theorem 2), alpha = 1/sqrt(T)."""
    return (4 * pc.f0_minus_fstar / math.sqrt(T)
            + 2 * B * B * pc.L ** 2 / T
            + 6 * pc.L * pc.sigma2 / math.sqrt(T)
            + 6 * pc.L ** 3 * B * B / (T * math.sqrt(T)))


def thm3_rhs(pc: ProblemConstants, B: float, T: int, p: int) -> float:
    """Parallel-step non-convex rate bound (Theorem 3), alpha = sqrt(p/T)."""
    return (8 * pc.f0_minus_fstar / math.sqrt(T * p)
            + 4 * B * B * pc.L ** 2 * p / T
            + 8 * pc.L * pc.sigma2 / math.sqrt(T * p)
            + 16 * pc.L ** 3 * B * B * p * math.sqrt(p) / (T * math.sqrt(T)))


def thm4_rhs(pc: ProblemConstants, B: float, T: int) -> float:
    """Single-step strongly-convex bound (Theorem 4)."""
    lt = math.log(T)
    return (pc.x0_dist2 / T
            + 16 * lt ** 2 * pc.L ** 2 * B * B / (pc.c ** 4 * T ** 2)
            + 12 * pc.sigma2 * lt / T
            + 48 * lt ** 3 * B * B * pc.L ** 2 / (pc.c ** 4 * T ** 3))


def thm5_rhs(pc: ProblemConstants, B: float, T: int, p: int) -> float:
    """Parallel-step strongly-convex bound (Theorem 5)."""
    ltp = math.log(T) + math.log(p)
    return (pc.x0_dist2 / (T * p)
            + 16 * ltp ** 2 * pc.L ** 2 * B * B / (pc.c ** 4 * T ** 2)
            + 12 * pc.sigma2 * ltp / (T * p)
            + 48 * ltp ** 3 * B * B * pc.L ** 2 / (pc.c ** 4 * T ** 3))


def lemma6_iters(B: float, eps: float) -> float:
    """Lower bound (Lemma 6): T = Omega(B^2/eps * log(1/eps))."""
    return B * B / eps * math.log(1.0 / eps)
