"""Optimization problems for the exact-semantics simulator.

Both expose the flat-vector interface the simulator uses:
  * ``dim``                            — parameter dimension d
  * ``loss(x)``                        — full objective f(x)
  * ``grad(x)``                        — exact gradient
  * ``batch_grads(views, key)``        — per-worker stochastic gradients at a
    (p, d) stack of views (vmapped + jitted)
  * ``constants()``                    — ProblemConstants for the theorems
  * ``m2_estimate`` / ``sigma2``       — second-moment / variance bounds

Pre-drawn gradient randomness (the fast path the ``lax.scan`` simulator
engine uses): on both testbeds the stochasticity of the gradient oracle is
*iterate-independent* — additive isotropic noise for :class:`Quadratic`,
minibatch index sampling for :class:`MLPClassification` — so a T-step run's
draws can be materialized in one batched PRNG call instead of T sequential
in-loop threefry calls (the dominant per-step cost on CPU):
  * ``presample_grads(key, T, p)``     — all gradient randomness for a run
  * ``batch_grads_at(views, draw)``    — deterministic gradients given one
    step's pre-drawn randomness ``draw = draws[t]``
``batch_grads(views, key)`` remains as the one-shot API (noise estimation,
single evaluations, problems that cannot presample).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.theory import ProblemConstants


class Quadratic:
    """Strongly convex quadratic f(x) = 0.5 (x-x*)' A (x-x*), stochastic
    gradients = exact gradient + isotropic noise with E||xi||^2 = sigma^2."""

    def __init__(self, dim: int = 64, cond: float = 10.0, sigma: float = 1.0,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        eigs = np.linspace(1.0, cond, dim)
        q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
        self.A = jnp.asarray(q @ np.diag(eigs) @ q.T, jnp.float32)
        self.x_star = jnp.asarray(rng.normal(size=dim), jnp.float32)
        self.dim = dim
        self.sigma = sigma
        self.L = float(eigs[-1])
        self.c = float(eigs[0])

    def loss(self, x):
        d = x - self.x_star
        return 0.5 * d @ (self.A @ d)

    def grad(self, x):
        return self.A @ (x - self.x_star)

    @functools.cached_property
    def _batch_grads(self):
        @jax.jit
        def f(views, key):
            g = jax.vmap(self.grad)(views)
            noise = jax.random.normal(key, views.shape) * (
                self.sigma / np.sqrt(self.dim))
            return g + noise
        return f

    def batch_grads(self, views, key):
        return self._batch_grads(views, key)

    def presample_grads(self, key, T: int, p: int):
        """All gradient noise for a T-step, p-worker run in one draw."""
        return self.presample_from_data(self.sim_data(), key, T, p)

    def batch_grads_at(self, views, draw):
        """Gradients at a (p, d) view stack given one step's noise (p, d)."""
        return jax.vmap(self.grad)(views) + draw

    # -- data-parameterized variants (fused / batched multi-problem paths) --
    # The simulator's fused step and `simulate_grid` trace one program and
    # feed the problem *as data*, so same-shape instances stack on a leading
    # batch axis (A (B, d, d), x_star (B, d)) and vmap across it.
    # `presample_grads` delegates to `presample_from_data` so fused and
    # unfused runs cannot drift apart in their noise draws; the parity
    # suite holds both to the same trajectory.

    def sim_data(self) -> dict:
        """The problem as a traceable pytree."""
        return {"A": self.A, "x_star": self.x_star,
                "sigma": jnp.float32(self.sigma)}

    def presample_from_data(self, data, key, T: int, p: int):
        d = data["x_star"].shape[-1]
        return jax.random.normal(key, (T, p, d)) * (
            data["sigma"] / np.sqrt(d))

    @staticmethod
    def grads_from_data(data, views, draw):
        """Row-major form of :meth:`batch_grads_at`: A is symmetric, so the
        per-view gradient stack is one (p, d) @ (d, d) MXU matmul."""
        return (views - data["x_star"][None, :]) @ data["A"] + draw

    @staticmethod
    def loss_from_data(data, x):
        dlt = x - data["x_star"]
        return 0.5 * dlt @ (data["A"] @ dlt)

    @staticmethod
    def grad_from_data(data, x):
        return data["A"] @ (x - data["x_star"])

    @functools.cached_property
    def _jit_batch_grads_at(self):
        return jax.jit(self.batch_grads_at)

    @property
    def sigma2(self) -> float:
        return self.sigma ** 2

    def m2_estimate(self, radius2: float) -> float:
        """Second-moment bound over ||x - x*||^2 <= radius2 (restricted set
        X, as the paper requires for strongly convex objectives)."""
        return self.L ** 2 * radius2 + self.sigma2

    def constants(self, x0) -> ProblemConstants:
        x0 = jnp.asarray(x0)
        return ProblemConstants(
            L=self.L, sigma2=self.sigma2,
            f0_minus_fstar=float(self.loss(x0)),
            c=self.c, x0_dist2=float(jnp.sum((x0 - self.x_star) ** 2)))


class MLPClassification:
    """Small two-layer MLP on a fixed synthetic classification set — the
    non-convex testbed. Stochastic gradients come from minibatch sampling."""

    def __init__(self, n_samples: int = 512, in_dim: int = 16,
                 hidden: int = 32, n_classes: int = 4, batch: int = 16,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        w_true = rng.normal(size=(in_dim, n_classes))
        xs = rng.normal(size=(n_samples, in_dim))
        logits = xs @ w_true + 0.5 * rng.normal(size=(n_samples, n_classes))
        ys = np.argmax(logits, axis=-1)
        self.xs = jnp.asarray(xs, jnp.float32)
        self.ys = jnp.asarray(ys, jnp.int32)
        self.batch = batch
        self.in_dim, self.hidden, self.n_classes = in_dim, hidden, n_classes
        self.shapes = [(in_dim, hidden), (hidden,), (hidden, n_classes),
                       (n_classes,)]
        self.dim = sum(int(np.prod(s)) for s in self.shapes)

    def init(self, seed: int = 1):
        rng = np.random.default_rng(seed)
        parts = [rng.normal(size=s) / np.sqrt(max(s[0], 1))
                 for s in self.shapes]
        return jnp.asarray(np.concatenate([p.reshape(-1) for p in parts]),
                           jnp.float32)

    def _unflatten(self, x):
        out, o = [], 0
        for s in self.shapes:
            n = int(np.prod(s))
            out.append(x[o:o + n].reshape(s))
            o += n
        return out

    def _loss_on(self, x, xs, ys):
        w1, b1, w2, b2 = self._unflatten(x)
        h = jnp.tanh(xs @ w1 + b1)
        logits = h @ w2 + b2
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=1))

    @functools.cached_property
    def _jit_loss(self):
        return jax.jit(lambda x: self._loss_on(x, self.xs, self.ys))

    def loss(self, x):
        return self._jit_loss(jnp.asarray(x))

    @functools.cached_property
    def _jit_grad(self):
        return jax.jit(jax.grad(lambda x: self._loss_on(x, self.xs, self.ys)))

    def grad(self, x):
        return self._jit_grad(jnp.asarray(x))

    @functools.cached_property
    def _batch_grads(self):
        def one(x, key):
            idx = jax.random.randint(key, (self.batch,), 0, self.xs.shape[0])
            return jax.grad(self._loss_on)(x, self.xs[idx], self.ys[idx])

        @jax.jit
        def f(views, key):
            keys = jax.random.split(key, views.shape[0])
            return jax.vmap(one)(views, keys)
        return f

    def batch_grads(self, views, key):
        return self._batch_grads(views, key)

    def presample_grads(self, key, T: int, p: int):
        """All minibatch index draws for a T-step, p-worker run."""
        return jax.random.randint(key, (T, p, self.batch), 0,
                                  self.xs.shape[0])

    def batch_grads_at(self, views, draw):
        """Gradients at a (p, d) view stack given one step's indices
        (p, batch)."""
        def one(x, idx):
            return jax.grad(self._loss_on)(x, self.xs[idx], self.ys[idx])
        return jax.vmap(one)(views, draw)

    @functools.cached_property
    def _jit_batch_grads_at(self):
        return jax.jit(self.batch_grads_at)

    def estimate_noise(self, x, n: int = 64, seed: int = 7):
        """Empirical (sigma2, m2) at x."""
        key = jax.random.PRNGKey(seed)
        views = jnp.broadcast_to(jnp.asarray(x), (n, self.dim))
        gs = self.batch_grads(views, key)
        mean = jnp.mean(gs, axis=0)
        sigma2 = float(jnp.mean(jnp.sum((gs - mean) ** 2, axis=-1)))
        m2 = float(jnp.mean(jnp.sum(gs ** 2, axis=-1)))
        return sigma2, m2

    def constants(self, x0, L_estimate: float = 20.0) -> ProblemConstants:
        sigma2, _ = self.estimate_noise(x0)
        return ProblemConstants(
            L=L_estimate, sigma2=sigma2,
            f0_minus_fstar=float(self.loss(x0)),  # f* >= 0 for CE loss
        )
