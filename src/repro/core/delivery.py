"""Delay rings and delivery-schedule precompute, shared by both engines.

This module owns the bounded-staleness *delivery* machinery that used to
live inline in `repro.core.sim_engine` (the testbed simulator) and is now
also consumed by `repro.dist.async_engine` (the real-model bounded-delay
trainer):

  * **Fixed-capacity delay rings** — the dynamic "pending messages" list of
    an asynchronous run, made jit-able: a ring of ``capacity`` slots indexed
    by ``step % capacity``.  A message produced at step ``t`` with delay
    ``d < capacity`` is deposited into slot ``(t + d) % capacity`` and taken
    (and the slot zeroed) at step ``t + d`` — every deposit is consumed
    exactly once, which is what makes gradient mass conservation provable
    (see ``tests/test_delivery.py``).  Capacity is bounded by the relaxation
    itself: ``tau_max + 1`` for bounded-delay async, 3 for the omission
    model (delivery in {t+1, t+2}).

  * **Per-worker staleness schedules** — ``make_tau_schedule`` pre-draws the
    oblivious-adversary delay table ``tau(t, worker)`` for the real-model
    engine (`repro.dist.async_engine`): at step ``t`` worker ``w``'s
    gradient is delivered at ``t + tau(t, w)``, with ``0 <= tau <= tau_max``
    (or :data:`DROPPED` for crashed workers).  Like the simulator schedules
    in `sim_types`, the table is drawn up-front from a dedicated numpy
    stream that never sees a gradient.

  * **Whole-run delivery tensors** — :func:`delivery_tensors` builds the
    fused simulator step's (T, m, p) delivery weights in one vectorized
    pass (moved here from ``kernels/sim_step/ops.py``; re-exported there).
    The tensors are schedule-determined, never iterate-dependent, and obey
    per-kind conservation laws: ``crash_subst`` rows of alive receivers sum
    to the number of globally-received gradients (substitution preserves
    mass), ``elastic_variance`` view rows always sum to exactly ``p`` and
    defer rows to exactly ``0`` (deferral is mass-neutral).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

#: Sentinel in a tau schedule: the worker is crashed at this step — its
#: gradient is never delivered (the engine masks the deposit to zero).
DROPPED = -1

#: Named staleness schedules understood by :func:`make_tau_schedule`.
TAU_SCHEDULES = ("constant", "uniform", "roundrobin", "straggler", "crash",
                 "rejoin")


# ---------------------------------------------------------------------------
# fixed-capacity delay rings (functional; usable inside scan/shard_map)
# ---------------------------------------------------------------------------

def ring_init(capacity: int, shape, dtype=jnp.float32) -> jax.Array:
    """A zeroed delay ring of ``capacity`` slots of ``shape``."""
    return jnp.zeros((capacity, *shape), dtype)


def ring_deposit(ring: jax.Array, slot, value) -> jax.Array:
    """Accumulate ``value`` into ``slot`` (several messages may land in the
    same slot; delivery sums them)."""
    return ring.at[slot].add(value)


def ring_take(ring: jax.Array, slot):
    """Consume ``slot``: returns ``(value, ring with the slot zeroed)``."""
    return ring[slot], ring.at[slot].set(jnp.zeros((), ring.dtype))


def ring_put(ring: jax.Array, slot, value) -> jax.Array:
    """Overwrite ``slot`` (publish/replace semantics).  Gradient delivery
    uses the accumulating :func:`ring_deposit`; *version* rings — e.g. the
    serving replica's parameter-version ring (`repro.serve.replica`), where
    slot ``v % capacity`` holds snapshot ``v`` and a republish replaces it —
    use this."""
    return ring.at[slot].set(value)


def tree_ring_init(capacity: int, tree, dtype=jnp.float32):
    """Per-leaf :func:`ring_init` over a pytree of arrays/shapes."""
    return jax.tree.map(
        lambda a: ring_init(capacity, jnp.shape(a), dtype), tree)


def tree_ring_put(rings, slot, tree):
    """Per-leaf :func:`ring_put` (overwrite) over a pytree."""
    return jax.tree.map(lambda r, v: ring_put(r, slot, v), rings, tree)


def tree_ring_read(rings, slot):
    """Read ``slot`` without consuming it (a version ring is read many
    times — unlike delivery rings, reads must not zero the slot)."""
    return jax.tree.map(lambda r: r[slot], rings)


def tree_ring_deposit(rings, slot, tree):
    return jax.tree.map(lambda r, v: ring_deposit(r, slot, v), rings, tree)


def tree_ring_take(rings, slot):
    taken = jax.tree.map(lambda r: r[slot], rings)
    rings = jax.tree.map(
        lambda r: r.at[slot].set(jnp.zeros((), r.dtype)), rings)
    return taken, rings


def delivery_plan(taus: jax.Array, step, cap: int):
    """Per-worker delivery plan for step ``step``'s fresh messages.

    The fused async engine (`repro.dist.async_engine`, ``overlap`` path)
    all-gathers each step's compact compressed payloads and decompresses
    every live message exactly ONCE, straight into the dense
    delivery-indexed accumulator ring at slot ``(step + tau) % cap`` (the
    `kernels.cr_reduce` deposit ops — one fused scatter-reduce of the
    whole panel).  Slot ``t % cap`` is taken (and zeroed) at the start of
    step ``t`` for the overlappable prior deliveries, and taken again
    after the deposit for the ``tau == 0`` self-deliveries, which land in
    the freshly-zeroed slot.  ``DROPPED`` (crashed) messages get weight 0
    and are never applied — the same mass loss as the dense rings'
    deposit masking.

    Returns ``(w_live (n,), slots (n,))`` over ``taus`` (horizon, n): the
    float32 0/1 aliveness weights of this step's n messages and the
    accumulator slot each lands in (``step % cap`` where the weight is 0
    — the write is zero there).
    """
    horizon, _ = taus.shape
    tau = taus[jnp.mod(step, horizon)]               # (n,) this step's delays
    w_live = (tau >= 0).astype(jnp.float32)
    slots = jnp.mod(step + jnp.clip(tau, 0, cap - 1), cap)
    return w_live, slots


# ---------------------------------------------------------------------------
# per-message delay masks (simulator async kind)
# ---------------------------------------------------------------------------

def delay_masks(delays, n_levels: int):
    """One-hot delay masks: (T, p, p) int delays -> (n_levels, T, p, p) f32.

    Level ``l`` is the messages delayed by exactly ``l`` steps.  For delays
    in ``[0, n_levels)`` the levels partition the messages: summed over
    levels every (t, i, j) entry is exactly 1 — each message is delivered
    exactly once (the "row-stochastic where required" delivery invariant).
    """
    delays = jnp.asarray(delays)
    return jnp.stack([(delays == l).astype(jnp.float32)
                      for l in range(n_levels)])


# ---------------------------------------------------------------------------
# per-worker staleness schedules (real-model async engine)
# ---------------------------------------------------------------------------

def make_tau_schedule(schedule: str, p: int, T: int, tau_max: int,
                      seed: int = 0) -> np.ndarray:
    """Pre-draw the (T, p) int32 delay table ``tau(t, worker)``.

    Worker ``w``'s step-``t`` gradient is delivered at ``t + tau(t, w)``;
    every entry satisfies ``0 <= tau <= tau_max`` except :data:`DROPPED`
    rows of crashed workers.  Schedules:

      constant   : every message delayed by exactly ``tau_max``
      uniform    : iid uniform over ``{0, ..., tau_max}``
      roundrobin : ``(t + w) % (tau_max + 1)`` — deterministic rotation
      straggler  : the last worker always at ``tau_max``, the rest at 0
      crash      : uniform delays, but the last ``max(1, p // 4)`` workers
                   crash at ``T // 2`` (DROPPED from then on)
      rejoin     : like ``crash`` but recovery is modeled too — the same
                   workers crash at ``T // 3`` and come back at
                   ``max(T // 3 + 1, (2 * T) // 3)``, resuming uniform
                   delays (DROPPED only inside the outage window)
    """
    if tau_max < 0:
        raise ValueError(f"tau_max must be >= 0, got {tau_max}")
    rng = np.random.default_rng(seed)
    t_idx = np.arange(T)[:, None]
    w_idx = np.arange(p)[None, :]
    if schedule == "constant":
        taus = np.full((T, p), tau_max)
    elif schedule == "uniform":
        taus = rng.integers(0, tau_max + 1, size=(T, p))
    elif schedule == "roundrobin":
        taus = (t_idx + w_idx) % (tau_max + 1)
    elif schedule == "straggler":
        taus = np.where(w_idx == p - 1, tau_max, 0) + 0 * t_idx
    elif schedule == "crash":
        taus = rng.integers(0, tau_max + 1, size=(T, p))
        n_crash = max(1, p // 4) if p > 1 else 0
        if n_crash:
            taus[T // 2:, p - n_crash:] = DROPPED
    elif schedule == "rejoin":
        taus = rng.integers(0, tau_max + 1, size=(T, p))
        n_crash = max(1, p // 4) if p > 1 else 0
        if n_crash:
            down = T // 3
            back = max(down + 1, (2 * T) // 3)
            taus[down:back, p - n_crash:] = DROPPED
    else:
        raise ValueError(
            f"unknown tau schedule {schedule!r}; one of {TAU_SCHEDULES}")
    return taus.astype(np.int32)


def validate_tau_table(taus: np.ndarray, tau_max: int) -> np.ndarray:
    """Check a measured/loaded (T, p) delay table against the delivery
    contract `make_tau_schedule` promises: int dtype, every entry in
    ``[0, tau_max]`` or exactly :data:`DROPPED`.  Tables that pass are
    safe for the delivery rings' exactly-once discipline (a delay beyond
    ``tau_max`` would alias a ring slot still holding an unconsumed
    message).  Returns the table as int32; raises ``ValueError`` on any
    violation.  This is the ingestion gate for externally *measured*
    staleness — e.g. `repro.cluster`'s event-loop traces."""
    taus = np.asarray(taus)
    if taus.ndim != 2:
        raise ValueError(f"tau table must be (T, p), got shape {taus.shape}")
    if not np.issubdtype(taus.dtype, np.integer):
        raise ValueError(f"tau table must be integer, got {taus.dtype}")
    if tau_max < 0:
        raise ValueError(f"tau_max must be >= 0, got {tau_max}")
    bad = (taus != DROPPED) & ((taus < 0) | (taus > tau_max))
    if bad.any():
        t, w = np.argwhere(bad)[0]
        raise ValueError(
            f"tau[{t}, {w}] = {taus[t, w]} outside [0, {tau_max}] "
            f"and not DROPPED ({np.count_nonzero(bad)} bad entries)")
    return taus.astype(np.int32)


def taus_to_message_delays(taus: np.ndarray) -> np.ndarray:
    """Broadcast a per-worker (T, p) delay table to the simulator's
    per-message (T, p, p) ``delays[t, receiver, sender]`` layout
    (`sim_types.make_schedule`'s async convention): every receiver sees
    sender ``j``'s step-``t`` gradient after ``tau(t, j)`` steps, except a
    worker's own gradient, which is always immediate (diagonal zero).
    :data:`DROPPED` senders keep DROPPED off-diagonal — `delay_masks`
    gives those messages no delivery level, i.e. they are never applied.
    This is the bridge from a *measured* cluster trace to the convergence
    simulator's staleness machinery."""
    taus = np.asarray(taus, np.int32)
    t_len, p = taus.shape
    delays = np.broadcast_to(taus[:, None, :], (t_len, p, p)).copy()
    idx = np.arange(p)
    delays[:, idx, idx] = 0
    return delays


# ---------------------------------------------------------------------------
# whole-run delivery tensors (fused simulator step)
# ---------------------------------------------------------------------------

def delivery_tensors(kind: str, p: int, T: int, per_step: dict,
                     per_run: dict, knobs: dict):
    """Precompute the whole run's delivery tensors, vectorized over T.

    Returns (U (T, m, p) float32, new_alive (T, p) bool or None).  Row 0 of
    each U[t] weights the x update, rows 1..p the view updates (rows of
    dead workers are zero, so no masking pass is needed downstream), rows
    p+1..2p (``elastic_variance`` only) the deferred-correction update.
    The step scale alpha/p is NOT folded in here — callers scale U once.
    """
    eye = jnp.eye(p, dtype=bool)
    if kind in ("crash", "crash_subst"):
        ts = jnp.arange(T)[:, None]
        crash_step = per_run["crash_step"]               # (p,)
        alive = crash_step[None, :] >= ts                # (T, p)
        crashing = crash_step[None, :] == ts
        new_alive = alive & ~crashing
        if "rejoin_step" in per_run:
            # crashed workers re-enter at rejoin_step (> crash_step; use
            # >= T for "never"): they rejoin the sender AND receiver sets,
            # so the conservation laws below must hold across re-entry too
            rejoined = ts >= per_run["rejoin_step"][None, :]
            alive = alive | rejoined
            new_alive = new_alive | rejoined
        base = alive[:, :, None] & alive[:, None, :]
        heard = (per_run["hear_u"].T[None] < 0.5) \
            & new_alive[:, :, None] & ~eye[None]
        recv = jnp.where(crashing[:, None, :], heard, base)
        in_recv = jnp.any(recv, axis=1)                  # (T, p)
        w_v = recv.astype(jnp.float32) * new_alive[:, :, None]
        if kind == "crash_subst":
            missed = jnp.sum((~recv) & in_recv[:, None, :], axis=2)
            w_v = w_v + eye[None] * (
                missed.astype(jnp.float32) * new_alive)[:, :, None]
        u = jnp.concatenate(
            [in_recv.astype(jnp.float32)[:, None], w_v], axis=1)
        return u, new_alive
    if kind == "elastic_variance":
        drop = (per_step["drop_u"] < knobs["drop_prob"]) & ~eye[None]
        nd = jnp.sum(drop, axis=2).astype(jnp.float32)   # (T, p)
        diag_nd = eye[None] * nd[:, :, None]
        w_v = jnp.ones((T, p, p), jnp.float32) + diag_nd - drop
        w_d = drop.astype(jnp.float32) - diag_nd
        u = jnp.concatenate(
            [jnp.ones((T, 1, p), jnp.float32), w_v, w_d], axis=1)
        return u, None
    raise ValueError(f"no delivery tensor for kind {kind!r}")
