"""Exact-semantics numpy oracle for the distributed models (Algs 1-6).

This is the readable, loop-per-worker reference implementation the compiled
``lax.scan`` engine (`repro.core.sim_engine`) is verified against
step-for-step.  All scheduling randomness comes from the pre-drawn
:class:`~repro.core.sim_types.Schedule` (see that module for the
oblivious-adversary RNG layout), so both engines see identical schedules;
gradient sampling uses the same ``PRNGKey(seed + 1)`` split chain.

Semantics are those of the paper's appendix algorithms: p workers hold views
``v`` (p, d); the auxiliary parameter ``x`` (Def. 1) accumulates every
generated gradient with weight alpha/p (parallel-steps rule, Eq. 11) or
alpha (single-steps rule, Eq. 10, shared-memory model).  The realized
elastic-consistency gap  max_i ||x_t - v_t^i||^2 / alpha^2  is measured
every step so Table 1's bounds can be checked against ground truth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core.sim_types import (Relaxation, Schedule, SimResult,
                                  make_schedule, make_shared_memory_schedule)


def simulate_ref(problem, relax: Relaxation, p: int, alpha: float, T: int,
                 seed: int = 0, x0=None, record_every: int = 10,
                 schedule: Optional[Schedule] = None) -> SimResult:
    """Run T parallel iterations of Eq. (11) under ``relax`` (numpy loop)."""
    if schedule is None:
        schedule = make_schedule(relax, p, problem.dim, T, seed)
    d = problem.dim
    grads_at = _make_grads_at(problem, seed, T, p)
    if x0 is None:
        x0 = np.zeros(d, np.float32)
    x = np.array(x0, np.float32)                  # auxiliary parameter
    v = np.tile(x0, (p, 1)).astype(np.float32)    # per-worker views
    alive = np.ones(p, bool)

    step_s, run_s = schedule.per_step, schedule.per_run
    pending: list = []     # list of (deliver_t, i_dst, vec) for delayed msgs
    err = np.zeros((p, d), np.float32)    # EF memories (Alg 6)

    losses, gnorms, gaps = [], [], []

    for t in range(T):
        if relax.kind == "adversarial":
            # Lemma 6 oracle: gradient evaluated at a point alpha*B away
            views_adv = x[None] + alpha * relax.B_adv * run_s["adv_dir"][None]
            g = grads_at(np.broadcast_to(views_adv, (p, d)), t)
        else:
            g = grads_at(v, t)                                        # (p, d)

        scale = alpha / p
        if relax.kind in ("sync", "adversarial"):
            upd = g[alive].sum(0) * scale
            x -= upd
            if relax.kind == "sync":
                v[alive] -= upd
            else:
                v[alive] = x[None]  # oracle controls the view directly

        elif relax.kind in ("crash", "crash_subst"):
            # delivery matrix: recv[i, j] — does i receive j's gradient?
            crashing = [j for j in range(p)
                        if alive[j] and run_s["crash_step"][j] == t]
            new_alive = alive.copy()
            new_alive[crashing] = False
            recv = np.ones((p, p), bool)
            recv[:, ~alive] = False
            recv[~alive, :] = False
            for j in crashing:
                # j computes+broadcasts, but only a random subset hears it;
                # same-step co-crashers never hear each other (symmetric rule)
                subset = run_s["hear_u"][j] < 0.5
                subset[j] = False
                recv[:, j] = subset & new_alive
            alive = new_alive
            in_i_t = recv.any(0)                      # sent to >= 1 node
            x -= scale * g[in_i_t].sum(0)
            for i in np.nonzero(alive)[0]:
                got = g[recv[i]].sum(0)
                if relax.kind == "crash_subst":
                    # Alg 1: substitute own grad for peers that crashed this
                    # step and weren't heard (they were alive last step)
                    missed = (~recv[i]) & in_i_t
                    got = got + g[i] * missed.sum()
                v[i] -= scale * got

        elif relax.kind == "omission":
            recv = np.ones((p, p), bool)
            n_out = len(pending)
            drop_u, extra = step_s["drop_u"][t], step_s["extra_delay"][t]
            for i in range(p):
                for j in range(p):
                    if i != j and n_out < relax.f and \
                            drop_u[i, j] < relax.drop_prob:
                        recv[i, j] = False
                        pending.append([t + 1 + int(extra[i, j]),
                                        i, scale * g[j]])
                        n_out += 1
            x -= scale * g.sum(0)
            for i in range(p):
                v[i] -= scale * g[recv[i]].sum(0)
            pending = _deliver(pending, v, t)

        elif relax.kind == "async":
            x -= scale * g.sum(0)
            delays = step_s["delays"][t]
            for i in range(p):
                for j in range(p):
                    if delays[i, j] == 0:
                        v[i] -= scale * g[j]
                    else:
                        pending.append([t + int(delays[i, j]), i,
                                        scale * g[j]])
            pending = _deliver(pending, v, t)

        elif relax.kind == "ef_comp":
            comp = relax.compressor
            payloads = np.zeros_like(g)
            for i in range(p):
                pay, e = C.ef_compress(comp, jnp.asarray(alpha * g[i]),
                                       jnp.asarray(err[i]))
                payloads[i] = np.asarray(pay)
                err[i] = np.asarray(e)
            x -= scale * g.sum(0)
            v -= payloads.sum(0)[None] / p

        elif relax.kind == "elastic_norm":
            # §5: proceed once received norm >= beta * ||own grad||;
            # leftovers apply next step (speculation depth 1).
            x -= scale * g.sum(0)
            norms = np.linalg.norm(g, axis=1)
            for i in range(p):
                order = step_s["perm"][t, i]
                got, acc = [i], norms[i] * 0.0
                target = relax.beta * norms[i]
                for j in order:
                    if j == i:
                        continue
                    if acc >= target:
                        pending.append([t + 1, i, scale * g[j]])
                    else:
                        got.append(j)
                        acc += norms[j]
                v[i] -= scale * g[got].sum(0)
            pending = _deliver(pending, v, t)

        elif relax.kind == "elastic_variance":
            # Alg 4: delayed peers' gradients replaced by own, corrected at
            # the next iteration once the real gradient arrives.
            x -= scale * g.sum(0)
            drop_u = step_s["drop_u"][t]
            for i in range(p):
                upd = g[i].copy()  # own gradient always available
                for j in range(p):
                    if j == i:
                        continue
                    if drop_u[i, j] < relax.drop_prob:
                        upd += g[i]                       # substitute
                        pending.append([t + 1, i, scale * (g[j] - g[i])])
                    else:
                        upd += g[j]
                v[i] -= scale * upd
            pending = _deliver(pending, v, t)

        else:
            raise ValueError(relax.kind)

        gap2 = float(np.max(np.sum((x[None] - v[alive]) ** 2, axis=1)))
        gaps.append(gap2 / alpha ** 2)
        if t % record_every == 0:
            losses.append(float(problem.loss(jnp.asarray(x))))
            gnorms.append(float(np.sum(np.asarray(
                problem.grad(jnp.asarray(x))) ** 2)))

    return SimResult(np.asarray(losses), np.asarray(gnorms),
                     np.asarray(gaps), x, record_every, alpha)


def _make_grads_at(problem, seed: int, T: int, p: int):
    """Per-step gradient oracle sharing the engine's RNG protocol.

    With ``presample_grads`` all gradient randomness is one batched draw at
    ``PRNGKey(seed + 1)`` (identical to the scan engine's pre-scan draw);
    otherwise fall back to the per-step ``split`` chain — the engine's
    fallback path splits in the same order, so parity holds either way.
    """
    key = jax.random.PRNGKey(seed + 1)
    if hasattr(problem, "presample_grads"):
        draws = problem.presample_grads(key, T, p)
        bga = getattr(problem, "_jit_batch_grads_at", problem.batch_grads_at)

        def grads_at(views, t):
            return np.asarray(bga(jnp.asarray(views), draws[t]))
        return grads_at

    state = {"key": key}

    def grads_at(views, t):
        state["key"], sub = jax.random.split(state["key"])
        return np.asarray(problem.batch_grads(jnp.asarray(views), sub))
    return grads_at


def _deliver(pending, v, t):
    """Apply every delayed message due at step t; return the survivors."""
    still = []
    for dt, i, vec in pending:
        if dt <= t:
            v[i] -= vec
        else:
            still.append([dt, i, vec])
    return still


def simulate_shared_memory_ref(problem, p: int, alpha: float, T: int,
                               tau_max: int, seed: int = 0, x0=None,
                               record_every: int = 10,
                               schedule: Optional[Schedule] = None
                               ) -> SimResult:
    """Asynchronous shared-memory model (§4.2, Alg 5): single-step updates
    (Eq. 10); each iteration's gradient is computed on a componentwise-stale
    snapshot v[c] = x_{t - tau_c}[c], tau_c < tau_max (interval contention).
    """
    if schedule is None:
        schedule = make_shared_memory_schedule(p, problem.dim, T, tau_max,
                                               seed)
    d = problem.dim
    grads_at = _make_grads_at(problem, seed, T, 1)
    if x0 is None:
        x0 = np.zeros(d, np.float32)
    x = np.array(x0, np.float32)
    hist = np.tile(x0, (tau_max + 1, 1)).astype(np.float32)  # ring buffer

    losses, gnorms, gaps = [], [], []
    for t in range(T):
        taus = schedule.per_step["taus"][t]
        idx = (t - taus) % (tau_max + 1)
        view = hist[idx, np.arange(d)]
        g = grads_at(view[None], t)[0]
        gaps.append(float(np.sum((x - view) ** 2)) / alpha ** 2)
        x = x - alpha * g
        hist[(t + 1) % (tau_max + 1)] = x
        if t % record_every == 0:
            losses.append(float(problem.loss(jnp.asarray(x))))
            gnorms.append(float(np.sum(np.asarray(
                problem.grad(jnp.asarray(x))) ** 2)))

    return SimResult(np.asarray(losses), np.asarray(gnorms),
                     np.asarray(gaps), x, record_every, alpha)
