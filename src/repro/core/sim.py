"""Exact-semantics simulator for the paper's distributed models (Algs 1-6).

p logical workers hold views ``v`` (p, d); the auxiliary/global parameter
``x`` (Def. 1) accumulates *every* generated gradient with weight alpha/p
(parallel-steps rule, Eq. 11) or alpha (single-steps rule, Eq. 10, used by
the shared-memory model). Each relaxation perturbs *delivery*, exactly as in
the paper's appendix algorithms; the simulator measures the realized
elastic-consistency gap  max_i ||x_t - v_t^i||^2 / alpha^2  every step, so
Table 1's bounds can be checked against ground truth.

Scheduling randomness is drawn from a dedicated ``np.random.default_rng``
stream, independent of the gradient-sampling keys — the paper's *oblivious
adversary* assumption, literally.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C


@dataclass(frozen=True)
class Relaxation:
    """Which consistency relaxation to simulate.

    kind:
      sync              — failure-free synchronous baseline (B = 0)
      crash             — Alg 2: f crash faults, no substitution
      crash_subst       — Alg 1: crash faults, receivers substitute own grad
      omission          — Alg 3: <= f outstanding delayed messages
      async             — B.4: per-message delay < tau_max
      ef_comp           — Alg 6: error-feedback compression (all-delivered)
      elastic_norm      — §5 norm-bounded scheduler (beta)
      elastic_variance  — Alg 4: 1-step delays, substitute-then-correct
      adversarial       — Lemma 6 oracle: view displaced by alpha*B
    """

    kind: str = "sync"
    f: int = 0                   # crash/omission fault bound
    tau_max: int = 1             # async delay bound
    drop_prob: float = 0.3       # per-message delay probability
    compressor: Optional[C.Compressor] = None
    beta: float = 0.8            # norm-bounded scheduler threshold
    B_adv: float = 0.0           # adversarial oracle displacement


@dataclass
class SimResult:
    losses: np.ndarray           # recorded every `record_every`
    grad_norms2: np.ndarray      # ||grad f(x_t)||^2 at the same cadence
    gap2_over_alpha2: np.ndarray # max_i ||x_t - v_t^i||^2 / alpha^2, per step
    x_final: np.ndarray
    record_every: int
    alpha: float

    @property
    def b_hat(self) -> float:
        """Empirical elastic-consistency constant sqrt(max_t E gap^2/a^2)."""
        return float(np.sqrt(np.max(self.gap2_over_alpha2)))

    @property
    def b_hat_mean(self) -> float:
        return float(np.sqrt(np.mean(self.gap2_over_alpha2)))


def simulate(problem, relax: Relaxation, p: int, alpha: float, T: int,
             seed: int = 0, x0=None, record_every: int = 10) -> SimResult:
    """Run T parallel iterations of Eq. (11) under ``relax``."""
    rng = np.random.default_rng(seed)             # oblivious adversary
    key = jax.random.PRNGKey(seed + 1)            # gradient sampling
    d = problem.dim
    if x0 is None:
        x0 = np.zeros(d, np.float32)
    x = np.array(x0, np.float32)                  # auxiliary parameter
    v = np.tile(x0, (p, 1)).astype(np.float32)    # per-worker views
    alive = np.ones(p, bool)

    # --- relaxation state ---
    crash_at = None
    if relax.kind.startswith("crash"):
        crashed_ids = rng.choice(p, size=relax.f, replace=False)
        crash_at = {int(i): int(rng.integers(1, max(T - 1, 2)))
                    for i in crashed_ids}
    pending: list = []     # list of (deliver_t, i_dst, vec) for delayed msgs
    err = np.zeros((p, d), np.float32)    # EF memories (Alg 6)
    adv_dir = rng.normal(size=d).astype(np.float32)
    adv_dir /= np.linalg.norm(adv_dir)

    losses, gnorms, gaps = [], [], []

    for t in range(T):
        key, sub = jax.random.split(key)
        g = np.asarray(problem.batch_grads(jnp.asarray(v), sub))  # (p, d)

        if relax.kind == "adversarial":
            # Lemma 6 oracle: gradient evaluated at a point alpha*B away
            views_adv = x[None] + alpha * relax.B_adv * adv_dir[None]
            key, sub = jax.random.split(key)
            g = np.asarray(problem.batch_grads(
                jnp.broadcast_to(jnp.asarray(views_adv), (p, d)), sub))

        scale = alpha / p
        if relax.kind in ("sync", "adversarial"):
            upd = g[alive].sum(0) * scale
            x -= upd
            if relax.kind == "sync":
                v[alive] -= upd
            else:
                v[alive] = x[None]  # oracle controls the view directly

        elif relax.kind in ("crash", "crash_subst"):
            # delivery matrix: recv[i, j] — does i receive j's gradient?
            recv = np.ones((p, p), bool)
            recv[:, ~alive] = False
            recv[~alive, :] = False
            for j, tc in crash_at.items():
                if t == tc and alive[j]:
                    # j computes+broadcasts, but only a random subset hears it
                    subset = rng.random(p) < 0.5
                    subset[j] = False
                    recv[:, j] = subset & alive
                    alive[j] = False
            in_i_t = recv.any(0)                      # sent to >= 1 node
            x -= scale * g[in_i_t].sum(0)
            for i in np.nonzero(alive)[0]:
                got = g[recv[i]].sum(0)
                if relax.kind == "crash_subst":
                    # Alg 1: substitute own grad for peers that crashed this
                    # step and weren't heard (they were alive last step)
                    missed = (~recv[i]) & in_i_t
                    got = got + g[i] * missed.sum()
                v[i] -= scale * got

        elif relax.kind == "omission":
            recv = np.ones((p, p), bool)
            n_out = len(pending)
            for i in range(p):
                for j in range(p):
                    if i != j and n_out < relax.f and \
                            rng.random() < relax.drop_prob:
                        recv[i, j] = False
                        pending.append([t + 1 + int(rng.integers(0, 2)),
                                        i, scale * g[j]])
                        n_out += 1
            x -= scale * g.sum(0)
            for i in range(p):
                v[i] -= scale * g[recv[i]].sum(0)
            still = []
            for dt, i, vec in pending:
                if dt <= t:
                    v[i] -= vec
                else:
                    still.append([dt, i, vec])
            pending = still

        elif relax.kind == "async":
            x -= scale * g.sum(0)
            for i in range(p):
                for j in range(p):
                    delay = 0 if i == j else int(
                        rng.integers(0, relax.tau_max))
                    if delay == 0:
                        v[i] -= scale * g[j]
                    else:
                        pending.append([t + delay, i, scale * g[j]])
            still = []
            for dt, i, vec in pending:
                if dt <= t:
                    v[i] -= vec
                else:
                    still.append([dt, i, vec])
            pending = still

        elif relax.kind == "ef_comp":
            comp = relax.compressor
            payloads = np.zeros_like(g)
            for i in range(p):
                pay, e = C.ef_compress(comp, jnp.asarray(alpha * g[i]),
                                       jnp.asarray(err[i]))
                payloads[i] = np.asarray(pay)
                err[i] = np.asarray(e)
            x -= scale * g.sum(0)
            v -= payloads.sum(0)[None] / p

        elif relax.kind == "elastic_norm":
            # §5: proceed once received norm >= beta * ||own grad||;
            # leftovers apply next step (speculation depth 1).
            x -= scale * g.sum(0)
            norms = np.linalg.norm(g, axis=1)
            for i in range(p):
                order = rng.permutation(p)
                got, acc = [i], norms[i] * 0.0
                target = relax.beta * norms[i]
                for j in order:
                    if j == i:
                        continue
                    if acc >= target:
                        pending.append([t + 1, i, scale * g[j]])
                    else:
                        got.append(j)
                        acc += norms[j]
                v[i] -= scale * g[got].sum(0)
            still = []
            for dt, i, vec in pending:
                if dt <= t:
                    v[i] -= vec
                else:
                    still.append([dt, i, vec])
            pending = still

        elif relax.kind == "elastic_variance":
            # Alg 4: delayed peers' gradients replaced by own, corrected at
            # the next iteration once the real gradient arrives.
            x -= scale * g.sum(0)
            for i in range(p):
                upd = g[i].copy()  # own gradient always available
                for j in range(p):
                    if j == i:
                        continue
                    if rng.random() < relax.drop_prob:
                        upd += g[i]                       # substitute
                        pending.append([t + 1, i, scale * (g[j] - g[i])])
                    else:
                        upd += g[j]
                v[i] -= scale * upd
            still = []
            for dt, i, vec in pending:
                if dt <= t:
                    v[i] -= vec                            # correction
                else:
                    still.append([dt, i, vec])
            pending = still

        else:
            raise ValueError(relax.kind)

        gap2 = float(np.max(np.sum((x[None] - v[alive]) ** 2, axis=1)))
        gaps.append(gap2 / alpha ** 2)
        if t % record_every == 0:
            losses.append(float(problem.loss(jnp.asarray(x))))
            gnorms.append(float(np.sum(np.asarray(
                problem.grad(jnp.asarray(x))) ** 2)))

    return SimResult(np.asarray(losses), np.asarray(gnorms),
                     np.asarray(gaps), x, record_every, alpha)


def simulate_shared_memory(problem, p: int, alpha: float, T: int,
                           tau_max: int, seed: int = 0, x0=None,
                           record_every: int = 10) -> SimResult:
    """Asynchronous shared-memory model (§4.2, Alg 5): single-step updates
    (Eq. 10); each iteration's gradient is computed on a componentwise-stale
    snapshot v[c] = x_{t - tau_c}[c], tau_c < tau_max (interval contention).
    """
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    d = problem.dim
    if x0 is None:
        x0 = np.zeros(d, np.float32)
    x = np.array(x0, np.float32)
    hist = np.tile(x0, (tau_max + 1, 1)).astype(np.float32)  # ring buffer

    losses, gnorms, gaps = [], [], []
    for t in range(T):
        taus = rng.integers(0, tau_max, size=d)
        idx = (t - taus) % (tau_max + 1)
        view = hist[idx, np.arange(d)]
        key, sub = jax.random.split(key)
        g = np.asarray(problem.batch_grads(jnp.asarray(view[None]), sub))[0]
        gaps.append(float(np.sum((x - view) ** 2)) / alpha ** 2)
        x = x - alpha * g
        hist[(t + 1) % (tau_max + 1)] = x
        if t % record_every == 0:
            losses.append(float(problem.loss(jnp.asarray(x))))
            gnorms.append(float(np.sum(np.asarray(
                problem.grad(jnp.asarray(x))) ** 2)))

    return SimResult(np.asarray(losses), np.asarray(gnorms),
                     np.asarray(gaps), x, record_every, alpha)
