"""Simulator for the paper's distributed models (Algs 1-6) — dispatch facade.

p logical workers hold views ``v`` (p, d); the auxiliary/global parameter
``x`` (Def. 1) accumulates *every* generated gradient with weight alpha/p
(parallel-steps rule, Eq. 11) or alpha (single-steps rule, Eq. 10, used by
the shared-memory model). Each relaxation perturbs *delivery*, exactly as in
the paper's appendix algorithms; the simulator measures the realized
elastic-consistency gap  max_i ||x_t - v_t^i||^2 / alpha^2  every step, so
Table 1's bounds can be checked against ground truth.

Engine selection
----------------
Two engines share identical semantics and identical randomness:

  engine="scan" (default) — `repro.core.sim_engine`: the whole T-step run is
      one jitted ``jax.lax.scan`` program (delivery matrices, fixed-capacity
      delay ring buffers, Pallas EF kernels); the host syncs once per run.
      ``simulate_sweep`` vmaps it over seeds for multi-seed figure sweeps.
  engine="ref" — `repro.core.sim_ref`: the numpy loop-per-worker oracle,
      kept as the exact-semantics reference the parity suite checks the
      scan engine against step-for-step.

Oblivious-adversary RNG layout
------------------------------
Scheduling randomness is pre-drawn from a dedicated
``np.random.default_rng(seed)`` stream into a dense
:class:`~repro.core.sim_types.Schedule` (draw layout documented in
`sim_types`); gradient sampling uses an independent
``jax.random.PRNGKey(seed + 1)`` stream — one batched ``presample_grads``
draw when the problem supports it (both built-in testbeds: their gradient
stochasticity is iterate-independent), a per-step ``split`` chain otherwise.
This is the paper's *oblivious adversary* assumption, literally: the
scheduler's coin flips are fixed before any gradient is seen.  Both engines
consume the same schedule and the same gradient draws, so a
(kind, seed, p, T) tuple determines one trajectory regardless of engine.
"""
from __future__ import annotations

from repro.core import sim_engine, sim_ref
from repro.core.sim_types import (Relaxation, Schedule, SimResult,  # noqa: F401
                                  make_schedule, make_shared_memory_schedule)
from repro.core.sim_engine import (GridResult, simulate_grid,  # noqa: F401
                                   simulate_sweep)


def simulate(problem, relax: Relaxation, p: int, alpha: float, T: int,
             seed: int = 0, x0=None, record_every: int = 10,
             engine: str = "scan", fused="auto") -> SimResult:
    """Run T parallel iterations of Eq. (11) under ``relax``.

    ``fused`` (scan engine only) selects the fused Pallas step fast path:
    ``"auto"`` uses it when the (problem, relaxation) pair supports it AND
    d is large enough for it to win (>= `sim_engine.AUTO_MIN_DIM`),
    ``False`` forces the unfused oracle step, ``True`` errors if
    unsupported.
    """
    if engine == "scan":
        return sim_engine.simulate_scan(problem, relax, p, alpha, T,
                                        seed=seed, x0=x0,
                                        record_every=record_every,
                                        fused=fused)
    if engine == "ref":
        return sim_ref.simulate_ref(problem, relax, p, alpha, T, seed=seed,
                                    x0=x0, record_every=record_every)
    raise ValueError(f"unknown engine {engine!r} (want 'scan' or 'ref')")


def simulate_shared_memory(problem, p: int, alpha: float, T: int,
                           tau_max: int, seed: int = 0, x0=None,
                           record_every: int = 10,
                           engine: str = "scan") -> SimResult:
    """Asynchronous shared-memory model (§4.2, Alg 5): single-step updates
    (Eq. 10); each iteration's gradient is computed on a componentwise-stale
    snapshot v[c] = x_{t - tau_c}[c], tau_c < tau_max (interval contention).
    """
    if engine == "scan":
        return sim_engine.simulate_shared_memory_scan(
            problem, p, alpha, T, tau_max, seed=seed, x0=x0,
            record_every=record_every)
    if engine == "ref":
        return sim_ref.simulate_shared_memory_ref(
            problem, p, alpha, T, tau_max, seed=seed, x0=x0,
            record_every=record_every)
    raise ValueError(f"unknown engine {engine!r} (want 'scan' or 'ref')")
