"""Jaxpr auditor: trace every registered entry point and check what ships.

Four checks over `repro.analysis.entrypoints`' registry:

  * **collective inventory** — walk each traced jaxpr (including every
    sub-jaxpr: shard_map bodies, scan bodies, cond branches) and count the
    collective primitives with their bytes-on-wire.  The byte model matches
    `repro.launch.dryrun.collective_bytes`: an all-reduce/psum moves ~2x
    its payload on a ring, gathers/permutes ~1x their *output* (which
    already carries the axis-size factor).  The point of the inventory is
    the *between-strategy ordering at equal scale*: the compressed sync
    strategies (topk_ef, onebit_ef) must put strictly fewer bytes on the
    wire than the dense ``sync`` baseline — the paper's communication
    reduction, checked against the programs actually traced, not the prose.
  * **callback / host-sync detector** — no ``pure_callback`` /
    ``io_callback`` / debug callback primitives anywhere in a hot-path
    jaxpr: a callback is a device->host round-trip per step.
  * **donation audit** — every entry declaring ``donate_argnums`` is
    AOT-compiled and must realize a nonzero input/output alias
    (``memory_analysis().alias_size_in_bytes``): donation that silently
    fails to alias doubles peak memory exactly where it was promised not
    to.
  * **retrace-hazard check** — each entry is built twice (and, where the
    registry provides a ``variant``, with a config that must not change
    the program: an async schedule seed, a simulator knob value) and the
    jaxprs are hashed after alpha-renaming; differing hashes mean the
    builder bakes per-config values into the trace — one recompile per
    config at production scale.

`train/exact` is GSPMD: its gradient all-reduce is inserted by the
compiler, so it does NOT appear in the jaxpr inventory (the manual
``elastic/sync`` entry is the dense-wire baseline instead); its compiled
HLO is still measured via `dryrun.collective_bytes` and reported in info.
"""
from __future__ import annotations

import hashlib
import re

import numpy as np

from repro.analysis.findings import Finding, Report

#: jaxpr-level collective primitives and their ring-traffic factors
#: (psum ~ all-reduce: 2x payload; gathers/permutes: 1x their output)
COLLECTIVE_FACTORS = {
    "psum": 2.0, "psum2": 2.0, "pmax": 2.0, "pmin": 2.0,
    "all_gather": 1.0, "all_to_all": 1.0, "ppermute": 1.0,
    "reduce_scatter": 1.0, "pgather": 1.0,
}

CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                  "callback")


def _f(rule, where, detail):
    return Finding(pass_name="audit", rule=rule, where=where, detail=detail)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def iter_eqns(jaxpr):
    """Depth-first over every equation, descending into sub-jaxprs held in
    eqn params (scan/while/cond bodies, shard_map/pjit inner jaxprs)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        for j in _as_jaxprs(v):
            yield j


def _as_jaxprs(v):
    if hasattr(v, "eqns"):                       # Jaxpr
        return [v]
    if hasattr(v, "jaxpr"):                      # ClosedJaxpr
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out = []
        for item in v:
            out.extend(_as_jaxprs(item))
        return out
    return []


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)
                   * np.dtype(aval.dtype).itemsize)
    except Exception:                            # abstract tokens etc.
        return 0


def collective_inventory(jaxpr) -> dict:
    """{prim: {"count": n, "bytes": weighted-bytes}} plus a total."""
    inv: dict = {}
    total = 0.0
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_FACTORS:
            continue
        b = COLLECTIVE_FACTORS[name] * sum(
            _aval_bytes(v.aval) for v in eqn.outvars)
        slot = inv.setdefault(name, {"count": 0, "bytes": 0.0})
        slot["count"] += 1
        slot["bytes"] += b
        total += b
    inv["wire_bytes"] = total
    return inv


def find_callbacks(jaxpr) -> list:
    hits = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if any(c in name for c in CALLBACK_PRIMS):
            hits.append(name)
    return hits


_VAR_RE = re.compile(r"\b[a-z]+(?=:)|\b[a-z]+\b(?=[, )\]])")


def jaxpr_hash(jaxpr) -> str:
    """Structural hash of a jaxpr.  Trace-local variable names are already
    assigned deterministically per trace (a, b, c, ...), so two traces of
    the same program stringify identically; hashing the text is enough."""
    return hashlib.sha1(str(jaxpr).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# per-entry audits
# ---------------------------------------------------------------------------

def trace_entry(entry):
    import jax
    fn, args = entry.build()
    return jax.make_jaxpr(fn)(*args)


def audit_entry(entry, *, compile_donation: bool = True) -> tuple:
    """(findings, info) for one registry entry."""
    import jax

    findings: list = []
    closed = trace_entry(entry)
    inv = collective_inventory(closed.jaxpr)
    info = {"collectives": inv, "jaxpr_hash": jaxpr_hash(closed.jaxpr),
            "eqns": sum(1 for _ in iter_eqns(closed.jaxpr))}

    for name in find_callbacks(closed.jaxpr):
        findings.append(_f("callback-in-hot-path", entry.name,
                           f"host callback primitive '{name}' inside a "
                           f"per-step program"))

    # retrace: a second build, and the registry's must-not-retrace variant
    h2 = jaxpr_hash(trace_entry(entry).jaxpr)
    if h2 != info["jaxpr_hash"]:
        findings.append(_f("retrace-hazard", entry.name,
                           "two builds of the same config trace to "
                           "different jaxprs (nondeterministic builder)"))
    if entry.variant is not None:
        fn_v, args_v = entry.variant()
        hv = jaxpr_hash(jax.make_jaxpr(fn_v)(*args_v).jaxpr)
        if hv != info["jaxpr_hash"]:
            findings.append(_f(
                "retrace-hazard", entry.name,
                "a config variant that must share the program traces to "
                "a different jaxpr (per-config recompile hazard)"))

    if compile_donation and entry.donate:
        fn, args = entry.build()
        try:
            compiled = jax.jit(fn, donate_argnums=entry.donate) \
                .lower(*args).compile()
        except Exception as e:  # noqa: BLE001 — report, don't crash the CLI
            findings.append(_f("donation-uncompilable", entry.name,
                               f"{type(e).__name__} while compiling with "
                               f"declared donate_argnums={entry.donate}"))
        else:
            ma = compiled.memory_analysis()
            alias = getattr(ma, "alias_size_in_bytes", 0)
            info["alias_bytes"] = int(alias)
            if alias <= 0:
                findings.append(_f(
                    "donation-unrealized", entry.name,
                    f"donate_argnums={entry.donate} declared but the "
                    f"compiled program aliases 0 bytes"))
            from repro.launch.dryrun import collective_bytes
            info["hlo_collective_bytes"] = collective_bytes(
                compiled.as_text())
    return findings, info


# ---------------------------------------------------------------------------
# cross-entry checks
# ---------------------------------------------------------------------------

#: compressed sync strategies that must strictly beat the dense baseline
MUST_BEAT_SYNC = ("topk_ef", "onebit_ef")


def wire_comparison(inventories: dict) -> tuple:
    """Strategy-tagged wire bytes + the compressed-beats-dense findings."""
    findings = []
    by_strategy = {}
    for name, info in inventories.items():
        strat = info.get("strategy")
        if strat:
            by_strategy[strat] = info["collectives"]["wire_bytes"]
    sync = by_strategy.get("sync")
    if sync is not None:
        for strat in MUST_BEAT_SYNC:
            b = by_strategy.get(strat)
            if b is not None and not b < sync:
                findings.append(_f(
                    "compressed-not-better", f"strategy/{strat}",
                    f"bytes-on-wire {b:.0f} >= dense sync baseline "
                    f"{sync:.0f} — the communication reduction is gone"))
        if sync <= 0:
            findings.append(_f("empty-baseline", "strategy/sync",
                               "dense sync baseline traces to zero wire "
                               "bytes — inventory is not seeing the "
                               "collectives"))
    return findings, by_strategy


def run(registry=None, *, groups=None, compile_donation: bool = True,
        data_parallel: int = 1) -> Report:
    """Audit every (selected) entry point; returns a Report whose
    ``info["audit"]`` carries the full per-entry inventory."""
    from repro.analysis import entrypoints as EP

    if registry is None:
        registry = EP.make_registry(data_parallel)
    rep = Report()
    inventories: dict = {}
    for entry in registry:
        if groups and entry.group not in groups:
            continue
        try:
            findings, info = audit_entry(
                entry, compile_donation=compile_donation)
        except Exception as e:  # noqa: BLE001 — an unbuildable entry is a finding
            rep.findings.append(_f(
                "entrypoint-broken", entry.name,
                f"{type(e).__name__} while tracing: {e}"))
            continue
        info["strategy"] = entry.strategy
        info["group"] = entry.group
        inventories[entry.name] = info
        rep.findings += findings
    cross, by_strategy = wire_comparison(inventories)
    rep.findings += cross
    rep.info["audit"] = {
        "entries": inventories,
        "bytes_on_wire_by_strategy": by_strategy,
        "data_parallel": data_parallel,
    }
    return rep
