"""CLI: ``python -m repro.analysis --all --baseline analysis/baseline.json``.

Runs the selected passes (``--lint`` / ``--audit`` / ``--rings``, or
``--all``), diffs the findings against the checked-in baseline, prints a
human summary, optionally writes the full findings JSON (``--json`` — the
CI artifact), and exits nonzero iff there are NEW findings — fingerprints
not in the baseline.  ``--update-baseline`` rewrites the baseline to
accept exactly the current findings (review the diff like any code
change); newly accepted findings must come with ``--justify '...'`` —
the write is refused otherwise, and a checked-in baseline carrying an
empty/TODO justification fails the run.

``--devices N`` forces N host devices (XLA_FLAGS, set before jax imports)
so the audited collectives carry real p > 1 avals; the default single
device preserves the between-strategy byte ordering at 1/p scale.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse():
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: jaxpr audit, ring model checker, "
                    "AST lint")
    ap.add_argument("--all", action="store_true", help="run every pass")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--rings", action="store_true")
    ap.add_argument("--baseline", default="analysis/baseline.json",
                    help="accepted-findings file (missing == empty)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to accept current findings")
    ap.add_argument("--justify", default="",
                    help="justification text for findings newly accepted "
                         "by --update-baseline (refused without one)")
    ap.add_argument("--json", default="",
                    help="write the full findings/inventory JSON here")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices for the audit meshes")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the donation-audit compiles (trace only)")
    ap.add_argument("--fast", action="store_true",
                    help="trimmed ring spaces (bench smoke mode)")
    ap.add_argument("--max-p", type=int, default=4,
                    help="ring checker worker bound (exhaustive <= 4)")
    ap.add_argument("--max-tau", type=int, default=3,
                    help="ring checker staleness bound (exhaustive <= 3)")
    return ap.parse_args()


def main() -> int:
    args = _parse()
    if not (args.all or args.lint or args.audit or args.rings):
        args.all = True
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    # jax (and everything that imports it) only after XLA_FLAGS is set
    from repro.analysis.findings import (Report, load_baseline,
                                         unjustified_entries, write_baseline)

    report = Report()
    timings = {}
    if args.all or args.lint:
        from repro.analysis import lint
        t0 = time.time()
        report.extend(lint.run())
        timings["lint"] = round(time.time() - t0, 1)
    if args.all or args.rings:
        from repro.analysis import rings
        t0 = time.time()
        report.extend(rings.run(max_p=args.max_p, max_tau=args.max_tau,
                                fast=args.fast))
        timings["rings"] = round(time.time() - t0, 1)
    if args.all or args.audit:
        from repro.analysis import audit
        t0 = time.time()
        report.extend(audit.run(
            compile_donation=not args.no_compile,
            data_parallel=max(args.devices, 1)))
        timings["audit"] = round(time.time() - t0, 1)
    report.info["timings_s"] = timings

    if args.update_baseline:
        try:
            write_baseline(args.baseline, report.findings,
                           {"*": args.justify} if args.justify else None)
        except ValueError as e:
            print(f"fail: {e}")
            return 1
        print(f"baseline updated: {args.baseline} "
              f"({len(report.findings)} accepted findings)")
        return 0

    baseline = load_baseline(args.baseline)
    new = report.new_findings(baseline)
    todo = unjustified_entries(args.baseline)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(report.to_json(baseline), fh, indent=1, default=str)

    n_base = len(report.findings) - len(new)
    for pass_name, t in timings.items():
        print(f"  {pass_name}: {t}s")
    if "audit" in report.info:
        strat = report.info["audit"]["bytes_on_wire_by_strategy"]
        print("bytes on wire by strategy (jaxpr model):")
        for k in sorted(strat, key=strat.get):
            print(f"  {k:24s} {strat[k]:>14.0f}")
    print(f"findings: {len(report.findings)} total, {n_base} baselined, "
          f"{len(new)} NEW")
    for f in new:
        print(f"  NEW {f}")
    for e in todo:
        print(f"  UNJUSTIFIED {e['rule']} {e['where']} "
              f"(fp {e['fingerprint']})")
    if new:
        print(f"fail: {len(new)} finding(s) not in {args.baseline} — fix "
              f"them or justify via --update-baseline --justify '...'")
        return 1
    if todo:
        print(f"fail: {len(todo)} baselined finding(s) without a real "
              f"justification in {args.baseline} — an accepted hazard "
              f"needs a written reason")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
