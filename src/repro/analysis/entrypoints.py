"""Registry of every public jitted entry point, at audit (smoke) scale.

One place that knows how to *build* each hot-path program the repo ships —
the GSPMD train step, the shard_map elastic step per sync strategy, the
bounded-staleness async step per (tau_max, compressor), the simulator's
per-kind run functions, and the serving prefill/decode steps (dense and
paged).  `repro.analysis.audit` traces these to jaxprs (collective
inventory, callback/transfer detection, retrace hashing) and compiles the
ones with a donation contract; `tests/test_analysis.py` pins the resulting
inventory as a golden file.

Builders are lazy (`EntryPoint.build()`) so the CLI can audit a subset
without paying for the rest, and deterministic so two builds of the same
entry must trace to the identical jaxpr (the retrace-hazard check).  Where
a config knob must NOT change the program (an async schedule seed, a
simulator knob value), ``variant`` builds that differently-configured
twin; the audit fails if the twin's jaxpr hash drifts — that is exactly a
recompile-per-config hazard.

Scale: the smallest same-family config (`reduced()` — 2 layers, d<=128)
with a (data_parallel, 1) host mesh.  The *structure* of the program —
which collectives run, what hits the wire per strategy, what is donated —
is scale-independent; only the byte counts scale, and those are compared
*between* strategies at equal scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

SMOKE_ARCH = "qwen3-1.7b"
BATCH, SEQ = 4, 32


@dataclass(frozen=True)
class EntryPoint:
    """One auditable jitted program.

    ``build()`` returns ``(fn, args)`` — the raw step function and a tuple
    of (abstract or concrete) example arguments.  ``donate`` is the
    donation contract of the production jit site; entries with one are
    compiled by the donation audit.  ``strategy`` tags entries that
    participate in the per-strategy bytes-on-wire comparison.
    """

    name: str
    group: str                       # train | elastic | async | sim | serve
    build: callable
    donate: tuple = ()
    strategy: str | None = None
    compile_entry: bool = False
    variant: callable | None = None  # must trace to the SAME jaxpr
    notes: str = ""


def _smoke_cfg():
    from repro.configs import get_config
    return get_config(SMOKE_ARCH).reduced()


def _mesh(data_parallel: int):
    from repro.jax_compat import make_mesh
    return make_mesh((data_parallel, 1), ("data", "model"))


def _train_fixture(data_parallel: int):
    from repro.dist import sharding as SH
    from repro.models import transformer as TF
    from repro.models.params import abstract_params, param_specs
    from repro.optim import momentum
    cfg = _smoke_cfg()
    mesh = _mesh(data_parallel)
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    ab_params = abstract_params(defs)
    opt = momentum(1e-2, 0.9)
    ab_opt = jax.eval_shape(opt.init, ab_params)
    batch = {"tokens": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32),
             "labels": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)}
    return cfg, mesh, flags, pspecs, ab_params, opt, ab_opt, batch


def _build_train_exact(data_parallel: int):
    from repro.dist.train import make_train_step
    cfg, _, flags, _, ab_params, opt, ab_opt, batch = \
        _train_fixture(data_parallel)
    return make_train_step(cfg, opt, flags), (ab_params, ab_opt, batch)


def _build_elastic(strategy: str, data_parallel: int, *,
                   track_gap: bool = False):
    from repro.core.scheduler import SyncConfig
    from repro.dist import sharding as SH
    from repro.dist.train import init_dist_sync_state, make_elastic_train_step
    cfg, mesh, flags, pspecs, ab_params, opt, ab_opt, batch = \
        _train_fixture(data_parallel)
    scfg = SyncConfig(strategy=strategy, axis_names=SH.data_axes(mesh),
                      gate="static" if strategy == "elastic" else "norm",
                      track_gap=track_gap)
    ab_sync = jax.eval_shape(
        lambda: init_dist_sync_state(scfg, mesh, ab_params))
    step = make_elastic_train_step(cfg, opt, mesh, scfg, pspecs, flags)
    return step, (ab_params, ab_opt, ab_sync, batch)


def _build_async(tau_max: int, compressor: str, data_parallel: int,
                 seed: int = 0, overlap: bool = True):
    from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                         make_async_train_step)
    cfg, mesh, flags, pspecs, ab_params, opt, ab_opt, batch = \
        _train_fixture(data_parallel)
    acfg = AsyncConfig(tau_max=tau_max, schedule="uniform",
                       compressor=compressor,
                       error_feedback=compressor != "none",
                       topk_ratio=1 / 8, horizon=64, seed=seed,
                       track_gap=False, overlap=overlap)
    ab_state = jax.eval_shape(
        lambda: init_async_state(acfg, mesh, ab_params,
                                 pspecs if acfg.fused else None))
    step = make_async_train_step(cfg, opt, mesh, acfg, pspecs, flags)
    return step, (ab_params, ab_opt, ab_state, batch)


SIM_KINDS = ("sync", "crash", "crash_subst", "omission", "async", "ef_comp",
             "elastic_norm", "elastic_variance", "adversarial")
_SIM_P, _SIM_T, _SIM_DIM = 4, 8, 8


def _sim_relax(kind: str, *, beta: float = 0.8):
    from repro.core import compression as C
    from repro.core.sim_types import Relaxation
    comp = C.topk_compressor(0.25) if kind == "ef_comp" else None
    return Relaxation(kind=kind, f=1 if kind.startswith("crash")
                      or kind == "omission" else 0,
                      tau_max=2, compressor=comp, beta=beta,
                      B_adv=0.5 if kind == "adversarial" else 0.0)


def _build_sim(kind: str, *, beta: float = 0.8):
    from repro.core.problems import Quadratic
    from repro.core.sim_engine import _build_run, _knob_values
    from repro.core.sim_types import make_schedule
    problem = Quadratic(dim=_SIM_DIM, seed=0)
    relax = _sim_relax(kind, beta=beta)
    run = _build_run(problem, relax, _SIM_P, _SIM_T, False)
    sched = make_schedule(relax, _SIM_P, _SIM_DIM, _SIM_T, seed=0)
    per_step = jax.tree.map(jnp.asarray, sched.per_step)
    per_run = jax.tree.map(jnp.asarray, sched.per_run)
    args = (jnp.zeros(_SIM_DIM, jnp.float32), jnp.float32(0.05),
            jax.random.PRNGKey(1), per_step, per_run, _knob_values(relax),
            None)
    return run, args


def _serve_fixture():
    from repro.models import transformer as TF
    from repro.models.params import abstract_params
    cfg = _smoke_cfg()
    flags = TF.RunFlags(remat=False)
    ab_params = abstract_params(TF.model_defs(cfg))
    return cfg, flags, ab_params


_SERVE_B, _SERVE_S = 2, 16


def _build_prefill_dense():
    from repro.dist.train import make_prefill_step
    cfg, flags, ab_params = _serve_fixture()
    step = make_prefill_step(cfg, _SERVE_S, flags)
    batch = {"tokens": jax.ShapeDtypeStruct((_SERVE_B, _SERVE_S), jnp.int32)}
    return step, (ab_params, batch)


def _build_decode_dense():
    from repro.dist.train import make_decode_step
    from repro.models import transformer as TF
    cfg, flags, ab_params = _serve_fixture()
    ab_cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, _SERVE_B, _SERVE_S, flags))
    tokens = jax.ShapeDtypeStruct((_SERVE_B, 1), jnp.int32)
    return make_decode_step(cfg, flags), (ab_params, ab_cache, tokens)


def _paged_fixture():
    from repro.serve.paged_cache import PagedCacheConfig, init_page_pool
    cfg, flags, ab_params = _serve_fixture()
    pcfg = PagedCacheConfig(page_size=4, num_pages=8, max_requests=2,
                            max_pages_per_seq=4)
    pools = jax.eval_shape(
        lambda: init_page_pool(cfg.n_layers, cfg.n_kv_heads or cfg.n_heads,
                               cfg.d_model // cfg.n_heads, pcfg))
    return cfg, flags, ab_params, pcfg, pools


def _build_decode_paged():
    from repro.serve.engine import make_paged_decode_step
    cfg, flags, ab_params, pcfg, (kp, vp) = _paged_fixture()
    r = pcfg.max_requests
    step = make_paged_decode_step(cfg, pcfg, flags)
    args = (ab_params, kp, vp,
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r, pcfg.max_pages_per_seq), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.bool_),
            jax.random.PRNGKey(0))
    return step, args


def _build_prefill_paged():
    from repro.serve.engine import make_paged_prefill_step
    cfg, flags, ab_params, pcfg, (kp, vp) = _paged_fixture()
    bucket_pages = 2
    step = make_paged_prefill_step(cfg, pcfg, bucket_pages, flags)
    args = (ab_params, kp, vp,
            jax.ShapeDtypeStruct((1, bucket_pages * pcfg.page_size),
                                 jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((bucket_pages,), jnp.int32),
            jax.random.PRNGKey(0))
    return step, args


_CLUSTER_T, _CLUSTER_P, _CLUSTER_TAU = 16, 4, 3


def _build_cluster(preset_name: str = "uniform"):
    from repro.cluster import preset
    from repro.cluster.perf import _build_event_scan, durations_table
    spec = preset(preset_name, p=_CLUSTER_P, steps=_CLUSTER_T)
    d, alive = durations_table(spec, _CLUSTER_T, 4e8, 4.7e6)
    fn = _build_event_scan(_CLUSTER_TAU)
    return fn, (jnp.asarray(d), jnp.asarray(alive),
                jnp.float32(spec.apply_s))


def make_registry(data_parallel: int = 1) -> list:
    """Every public jitted entry point at audit scale.

    ``data_parallel`` sizes the host mesh's data axis — run the CLI with
    ``--devices 2`` (forced host devices) for jaxprs whose collectives
    carry real p > 1 avals; at p = 1 the *set* of collectives and the
    between-strategy byte ordering are unchanged.
    """
    p = data_parallel
    reg = [
        EntryPoint(
            "train/exact", "train", lambda: _build_train_exact(p),
            donate=(0, 1), compile_entry=True,
            notes="GSPMD data parallelism; the gradient all-reduce is "
                  "compiler-inserted, so it is visible in compiled HLO "
                  "only, not the jaxpr"),
        EntryPoint(
            "elastic/sync", "elastic", lambda: _build_elastic("exact", p),
            donate=(0, 1, 2), strategy="sync", compile_entry=True,
            notes="manual shard_map pmean — the dense-wire baseline every "
                  "compressed strategy must beat"),
        EntryPoint(
            "elastic/topk_ef", "elastic",
            lambda: _build_elastic("topk_ef", p),
            donate=(0, 1, 2), strategy="topk_ef", compile_entry=True,
            variant=lambda: _build_elastic("topk_ef", p)),
        EntryPoint(
            "elastic/onebit_ef", "elastic",
            lambda: _build_elastic("onebit_ef", p),
            donate=(0, 1, 2), strategy="onebit_ef", compile_entry=True),
        EntryPoint(
            "elastic/elastic", "elastic",
            lambda: _build_elastic("elastic", p),
            donate=(0, 1, 2), strategy="elastic", compile_entry=True,
            notes="static gate, phase 0"),
        EntryPoint(
            "elastic/topk_ef+gap", "elastic",
            lambda: _build_elastic("topk_ef", p, track_gap=True),
            strategy="topk_ef+gap",
            notes="track_gap=True costs a full-width pmean of the EF "
                  "residual for the gap2 metric — kept OUT of the "
                  "hot-path wire comparison on purpose"),
        EntryPoint(
            "async/tau0", "async", lambda: _build_async(0, "none", p),
            donate=(0, 1, 2), strategy="async_tau0", compile_entry=True,
            variant=lambda: _build_async(0, "none", p, seed=7),
            notes="capacity-1 ring == synchronous; seed variant must not "
                  "retrace (the tau table is state, not program)"),
        EntryPoint(
            "async/tau4", "async", lambda: _build_async(4, "none", p),
            donate=(0, 1, 2), strategy="async_tau4", compile_entry=True,
            variant=lambda: _build_async(4, "none", p, seed=7)),
        EntryPoint(
            "async/tau4_topk_ef", "async",
            lambda: _build_async(4, "topk", p),
            donate=(0, 1, 2), strategy="async_tau4_topk_ef",
            compile_entry=True,
            variant=lambda: _build_async(4, "topk", p, seed=7),
            notes="fused overlap path: the wire is one compact "
                  "(vals, idx) all-gather per step; delivery is the "
                  "cr_reduce masked decompress-reduce from the payload "
                  "ring — no dense pmean anywhere in the program"),
        EntryPoint(
            "async/tau4_topk_ef_densified", "async",
            lambda: _build_async(4, "topk", p, overlap=False),
            donate=(0, 1, 2), strategy="async_tau4_topk_ef_densified",
            compile_entry=True,
            notes="overlap=False escape hatch (tensor-parallel meshes): "
                  "compressed deposits densify into the full-width ring "
                  "and pmean dense — same trajectory as the fused path, "
                  "sync-sized wire"),
        EntryPoint(
            "async/tau4_onebit_ef", "async",
            lambda: _build_async(4, "onebit", p),
            donate=(0, 1, 2), strategy="async_tau4_onebit_ef",
            compile_entry=True,
            variant=lambda: _build_async(4, "onebit", p, seed=7),
            notes="fused overlap path, sign/mean wire form (bool bitmap "
                  "+ 2 means per row)"),
        EntryPoint(
            "cluster/event_scan", "cluster",
            lambda: _build_cluster("uniform"),
            variant=lambda: _build_cluster("straggler_heavy"),
            notes="discrete-event cluster loop (repro.cluster.perf): the "
                  "trace tables are data, not program — a different "
                  "cluster shape must not retrace; collective-free by "
                  "construction (host-side co-simulation)"),
        EntryPoint(
            "serve/prefill_dense", "serve", _build_prefill_dense,
            compile_entry=True),
        EntryPoint(
            "serve/decode_dense", "serve", _build_decode_dense,
            donate=(1,), compile_entry=True),
        EntryPoint(
            "serve/prefill_paged", "serve", _build_prefill_paged,
            donate=(1, 2), compile_entry=True),
        EntryPoint(
            "serve/decode_paged", "serve", _build_decode_paged,
            donate=(1, 2), compile_entry=True),
    ]
    for kind in SIM_KINDS:
        reg.append(EntryPoint(
            f"sim/{kind}", "sim",
            lambda kind=kind: _build_sim(kind),
            variant=(lambda kind=kind: _build_sim(kind, beta=0.5))
            if kind == "elastic_norm" else None,
            notes="whole-run scan; knobs are traced floats, so knob "
                  "changes must not retrace"))
    return reg


def by_name(registry: list) -> dict:
    return {e.name: e for e in registry}
