"""Exhaustive model checker for the delivery-ring disciplines.

`tests/test_delivery.py` samples the ring invariants with hypothesis; this
module *enumerates* them: for every staleness schedule with ``tau <=
tau_max`` (plus :data:`~repro.core.delivery.DROPPED` crash entries) up to a
bounded horizon, it checks the exact index arithmetic the engines use —
deposit at ``(t + tau) % capacity``, take at ``t % capacity``, capacity
``tau_max + 1`` — and turns the sampled properties into checked theorems
for the bounded model:

  * **exactly-once delivery** — every non-dropped deposit is taken exactly
    once, at exactly ``t + tau``;
  * **deposit-before-take ordering** — a ``tau = 0`` message is visible to
    the same step's take (the engines deposit before taking);
  * **no slot aliasing** — two messages never share a live slot unless
    they are due the same step (the accumulate-then-deliver case), which
    is precisely what capacity ``tau_max + 1`` buys.  A *negative control*
    re-runs the prover at capacity ``tau_max`` and must find aliasing —
    the checker's teeth are themselves checked;
  * **crash / rejoin mass conservation** — `delivery_tensors`' per-kind
    conservation laws, enumerated over every (crash_step, rejoin_step)
    assignment for ``p <= 4`` workers;
  * **version-ring staleness** (`repro.serve.replica`) — for every
    publish/refresh interleaving and lag schedule, the served snapshot is
    the version claimed and lags ``latest`` by at most ``tau_serve``.

Three layers keep each other honest: a *python reference model* (explicit
slot multisets — the spec), a *vectorized numpy prover* (the full
enumeration), and the *real implementations* (`repro.core.delivery` jnp
ring ops driven through ``lax.scan``/``vmap``; the real `ParamReplica`) on
the same schedule spaces.  Worker rings never interact — each worker
deposits only into its own ring (the ``buf`` leaves of
`repro.dist.async_engine` carry a leading worker dim) — so per-ring
exhaustiveness composes to ``p`` workers; the checker still enumerates the
joint space outright wherever it stays under the budget.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.analysis.findings import Finding, Report
from repro.core import delivery as DLV
from repro.core.delivery import DROPPED

#: Joint-enumeration budget: above this many schedules the checker switches
#: from the joint product space to per-ring exhaustion (sound by worker-ring
#: independence, which `check_worker_ring_independence` witnesses).
JOINT_LIMIT = 600_000


def _f(rule: str, where: str, detail: str) -> Finding:
    return Finding(pass_name="rings", rule=rule, where=where, detail=detail)


# ---------------------------------------------------------------------------
# layer 1: python reference model (the spec, executable)
# ---------------------------------------------------------------------------

def simulate_ring_model(taus, cap: int) -> dict:
    """Explicit slot-multiset simulation of one delivery ring.

    Returns {"delivered": {produce_step: deliver_step}, "violations": [...]}
    — the reference the vectorized prover is checked against.
    """
    horizon = len(taus)
    slots = [[] for _ in range(cap)]      # slot -> [(produced, due)]
    delivered: dict = {}
    violations = []
    for t in range(horizon):
        tau = taus[t]
        if tau != DROPPED:                # deposit before take (engine order)
            due = t + tau
            slot = due % cap
            for (_, other_due) in slots[slot]:
                if other_due != due:
                    violations.append(
                        f"alias@t={t}: slot {slot} holds due={other_due}, "
                        f"depositing due={due}")
            slots[slot].append((t, due))
        taken, slots[t % cap] = slots[t % cap], []
        for (s, due) in taken:
            if due != t:
                violations.append(f"mistimed: produced@{s} due@{due} "
                                  f"taken@{t}")
            if s in delivered:
                violations.append(f"double-delivery of message {s}")
            delivered[s] = t
    for s, tau in enumerate(taus):
        if tau != DROPPED and s + tau < horizon and s not in delivered:
            violations.append(f"lost: message {s} (tau={tau}) never taken")
    return {"delivered": delivered, "violations": violations}


# ---------------------------------------------------------------------------
# layer 2: vectorized prover (full enumeration)
# ---------------------------------------------------------------------------

def enumerate_schedules(tau_max: int, horizon: int, rings: int = 1,
                        crashes: bool = True) -> np.ndarray:
    """Every tau assignment: (N, horizon, rings) int8 over
    {DROPPED, 0..tau_max} (or {0..tau_max} with ``crashes=False``)."""
    vals = ([DROPPED] if crashes else []) + list(range(tau_max + 1))
    cols = horizon * rings
    grids = np.meshgrid(*([np.asarray(vals, np.int8)] * cols),
                        indexing="ij")
    flat = np.stack([g.reshape(-1) for g in grids], axis=1)
    return flat.reshape(-1, horizon, rings)


@dataclass
class RingCheckResult:
    n_schedules: int = 0
    n_messages: int = 0
    findings: list = field(default_factory=list)


def prove_ring_schedules(taus: np.ndarray, cap: int,
                         where: str) -> RingCheckResult:
    """Vectorized proof over a (N, H, R) schedule tensor for rings of
    capacity ``cap``: exactly-once at ``t + tau``, no cross-due slot
    aliasing, conservation ``delivered + in_flight + dropped == H*R``."""
    n, horizon, rings = taus.shape
    res = RingCheckResult(n_schedules=n)
    t = np.arange(horizon).reshape(1, horizon, 1)
    valid = taus != DROPPED
    due = np.where(valid, t + taus, -1)
    res.n_messages = int(valid.sum())

    # delivery step realized by take-at-(t % cap): the first t' >= t with
    # t' ≡ due (mod cap) — equals due iff the message fits the capacity
    deliv = t + (due - t) % cap
    bad = valid & (deliv != due)
    if bad.any():
        res.findings.append(_f(
            "mistimed-delivery", where,
            f"{int(bad.any(axis=(1, 2)).sum())}/{n} schedules deliver a "
            f"message at a step other than t+tau (capacity {cap})"))

    # slot aliasing: messages produced at t1 < t2 in the same ring whose
    # dues differ but share a slot while both are live (t2 <= due1 — msg1
    # is only removed by the take at its due step)
    d1 = due[:, :, None, :]               # (N, t1, 1, R)
    d2 = due[:, None, :, :]               # (N, 1, t2, R)
    v1 = valid[:, :, None, :]
    v2 = valid[:, None, :, :]
    t1 = t.reshape(1, horizon, 1, 1)
    t2 = t.reshape(1, 1, horizon, 1)
    alias = (v1 & v2 & (t1 < t2) & (t2 <= d1)
             & (d1 % cap == d2 % cap) & (d1 != d2))
    if alias.any():
        res.findings.append(_f(
            "slot-alias", where,
            f"{int(alias.any(axis=(1, 2, 3)).sum())}/{n} schedules alias a "
            f"live slot across different delivery steps (capacity {cap})"))

    # conservation: every message is delivered in-horizon, still in flight
    # (due beyond the horizon), or explicitly dropped — mass never vanishes
    delivered = valid & (due < horizon) & (deliv == due)
    in_flight = valid & (due >= horizon)
    dropped = ~valid
    total = delivered.sum() + in_flight.sum() + dropped.sum()
    if int(total) != n * horizon * rings:
        res.findings.append(_f(
            "mass-leak", where,
            f"delivered+in_flight+dropped = {int(total)} != "
            f"{n * horizon * rings} messages"))
    return res


# ---------------------------------------------------------------------------
# layer 3: the real jnp ring ops as ground truth
# ---------------------------------------------------------------------------

def jnp_ring_deliveries(taus: np.ndarray, cap: int) -> np.ndarray:
    """Drive `repro.core.delivery`'s actual ring ops (deposit-then-take per
    step, one-hot message payloads) over a (B, H) schedule batch with one
    ``vmap``-ed ``lax.scan``; returns the (B, H, H) delivery matrix
    ``out[b, t, s] = 1`` iff schedule b delivers message s at step t."""
    import jax
    import jax.numpy as jnp

    horizon = taus.shape[1]

    def one(tau_row):
        def body(ring, t):
            tau = tau_row[t]
            onehot = ((jnp.arange(horizon) == t)
                      & (tau != DROPPED)).astype(jnp.float32)
            ring = DLV.ring_deposit(ring, (t + jnp.maximum(tau, 0)) % cap,
                                    onehot)
            taken, ring = DLV.ring_take(ring, t % cap)
            return ring, taken

        _, out = jax.lax.scan(body, DLV.ring_init(cap, (horizon,)),
                              jnp.arange(horizon))
        return out

    return np.asarray(jax.jit(jax.vmap(one))(jnp.asarray(taus, jnp.int32)))


def check_ground_truth(taus: np.ndarray, cap: int, where: str) -> list:
    """Real ring ops vs the closed-form delivery law, whole batch at once."""
    n, horizon = taus.shape
    got = jnp_ring_deliveries(taus, cap)
    t = np.arange(horizon)
    due = t[None, :] + np.maximum(taus, 0)
    expect = np.zeros((n, horizon, horizon), np.float32)
    s_idx, b_idx = np.meshgrid(t, np.arange(n), indexing="xy")
    ok = (taus != DROPPED) & (due < horizon)
    expect[b_idx[ok], due[ok], s_idx[ok]] = 1.0
    if not np.array_equal(got, expect):
        n_bad = int((got != expect).any(axis=(1, 2)).sum())
        return [_f("jnp-divergence", where,
                   f"core.delivery ring ops diverge from the proven "
                   f"delivery law on {n_bad}/{n} schedules")]
    return []


def check_worker_ring_independence(p: int, tau_max: int, horizon: int,
                                  seed: int = 0) -> list:
    """Witness that per-worker rings do not interact: drive the real
    ``tree_ring_*`` ops with a worker-leading ``(p, cap, H)`` buffer (the
    `repro.dist.async_engine` state layout) on a random joint schedule and
    check every worker's deliveries match its OWN single-ring run."""
    rng = np.random.default_rng(seed)
    joint = rng.integers(DROPPED, tau_max + 1, size=(p, horizon))
    cap = tau_max + 1
    per_worker = jnp_ring_deliveries(joint, cap)           # (p, H, H)
    import jax.numpy as jnp
    rings = jnp.zeros((p, cap, horizon))
    got = np.zeros((p, horizon, horizon), np.float32)
    for t in range(horizon):
        tau = jnp.asarray(np.maximum(joint[:, t], 0))
        onehot = ((jnp.arange(horizon) == t)[None]
                  & (joint[:, t] != DROPPED)[:, None]).astype(jnp.float32)
        slots = (t + tau) % cap
        rings = rings.at[jnp.arange(p), slots].add(onehot)
        got[:, t] = np.asarray(rings[:, t % cap])
        rings = rings.at[:, t % cap].set(0.0)
    if not np.array_equal(got, per_worker):
        return [_f("worker-coupling", f"async-buf/p{p}",
                   "worker-dim ring deliveries differ from independent "
                   "single-ring runs — rings interact")]
    return []


# ---------------------------------------------------------------------------
# gradient delivery rings: full check
# ---------------------------------------------------------------------------

def check_gradient_rings(tau_max: int, p: int, horizon: int, *,
                         ground_truth: bool = True) -> tuple:
    """All three layers for the bounded-staleness gradient rings at one
    (tau_max, p, horizon) point.  Returns (findings, stats)."""
    cap = tau_max + 1
    where = f"delivery-ring/tau{tau_max}/p{p}/H{horizon}"
    findings: list = []

    joint_size = (tau_max + 2) ** (horizon * p)
    if joint_size <= JOINT_LIMIT:
        taus = enumerate_schedules(tau_max, horizon, rings=p)
        mode = "joint"
    else:
        # per-ring exhaustion; composes by ring independence (witnessed)
        taus = enumerate_schedules(tau_max, horizon, rings=1)
        mode = "per-ring"
        findings += check_worker_ring_independence(p, tau_max, horizon)
    res = prove_ring_schedules(taus, cap, where)
    findings += res.findings

    # the python reference model must agree with the prover (spec vs proof)
    flat = taus.reshape(taus.shape[0], -1)
    stride = max(1, flat.shape[0] // 512)
    for row in flat[::stride]:
        for r in range(taus.shape[2]):
            model = simulate_ring_model(list(row[r::taus.shape[2]]), cap)
            if model["violations"]:
                findings.append(_f(
                    "model-divergence", where,
                    f"reference model violations on a prover-clean "
                    f"schedule: {model['violations'][0]}"))
                break

    if ground_truth:
        single = (taus[:, :, 0] if mode == "joint"
                  else taus.reshape(-1, horizon))
        stride = max(1, single.shape[0] // 4096)
        findings += check_ground_truth(single[::stride], cap, where)

    stats = {"mode": mode, "schedules": res.n_schedules,
             "messages": res.n_messages, "capacity": cap}
    return findings, stats


def check_negative_control(tau_max: int, horizon: int) -> list:
    """The prover must FIND aliasing at capacity ``tau_max`` (one slot
    short) — otherwise the checker itself is broken."""
    if tau_max < 1:
        return []
    taus = enumerate_schedules(tau_max, horizon, rings=1, crashes=False)
    res = prove_ring_schedules(taus, tau_max,
                               f"negative-control/tau{tau_max}")
    if not any(f.rule in ("slot-alias", "mistimed-delivery")
               for f in res.findings):
        return [_f("toothless-checker", f"negative-control/tau{tau_max}",
                   f"capacity {tau_max} (one short) produced no aliasing "
                   f"finding — the prover has lost its teeth")]
    return []


# ---------------------------------------------------------------------------
# crash / rejoin mass conservation (delivery_tensors)
# ---------------------------------------------------------------------------

def _conservation_violations(kind: str, u: np.ndarray, alive: np.ndarray,
                             where: str) -> list:
    """The per-kind conservation laws of `delivery_tensors`, batched over a
    leading config axis: u (B, T, 1+p, p), alive (B, T, p)."""
    findings = []
    in_recv = u[:, :, 0, :]
    if not np.all((in_recv == 0) | (in_recv == 1)):
        findings.append(_f("x-row-weight", where,
                           "x applies some gradient with weight not in "
                           "{0, 1}"))
    rows = u[:, :, 1:, :]
    if np.any(rows[~alive] != 0):
        findings.append(_f("dead-row-mass", where,
                           "a dead worker's view row carries mass"))
    row_sums = rows.sum(axis=3)
    expect = in_recv.sum(axis=2)[:, :, None]
    if kind == "crash_subst":
        bad = alive & ~np.isclose(row_sums,
                                  np.broadcast_to(expect, row_sums.shape))
        if bad.any():
            findings.append(_f(
                "mass-not-conserved", where,
                f"substitution fails to conserve mass in "
                f"{int(bad.any(axis=(1, 2)).sum())}/{u.shape[0]} configs"))
    else:
        if np.any(row_sums > expect + 1e-6):
            findings.append(_f("mass-created", where,
                               "crash without substitution creates mass"))
    return findings


def check_crash_rejoin_conservation(p: int, t_steps: int,
                                    chunk: int = 8192) -> tuple:
    """Enumerate EVERY (crash_step, rejoin_step) assignment for ``p``
    workers over ``t_steps`` steps — crash at any step or never; rejoin at
    any later step or never — against both hear-patterns (all crashing
    broadcasts heard / none), for both crash kinds.  One vmapped
    `delivery_tensors` call per chunk; numpy checks the laws."""
    import jax
    import jax.numpy as jnp

    findings: list = []
    never_c, never_r = t_steps, 2 * t_steps
    pairs = [(c, r) for c in range(t_steps + 1)
             for r in (range(c + 1, t_steps + 1) if c < t_steps else [])] \
        + [(never_c, never_r)]
    pairs += [(c, never_r) for c in range(t_steps)]       # crash, never rejoin
    combos = np.asarray(list(itertools.product(pairs, repeat=p)),
                        np.int32)                          # (B, p, 2)
    crash, rejoin = combos[:, :, 0], combos[:, :, 1]
    n_cfg = 0
    for kind in ("crash", "crash_subst"):
        where = f"delivery-tensors/{kind}/p{p}/T{t_steps}"
        fn = jax.jit(jax.vmap(
            lambda cs, rs, hu, kind=kind: DLV.delivery_tensors(
                kind, p, t_steps, {},
                {"crash_step": cs, "rejoin_step": rs, "hear_u": hu}, {})))
        for hear in (0.0, 1.0):
            # hear_u[j, i] < 0.5 == receiver i hears j's crashing broadcast
            hu = jnp.full((p, p), hear)
            for lo in range(0, len(combos), chunk):
                cs = jnp.asarray(crash[lo:lo + chunk])
                rs = jnp.asarray(rejoin[lo:lo + chunk])
                u, alive = fn(cs, rs,
                              jnp.broadcast_to(hu, (cs.shape[0], p, p)))
                findings += _conservation_violations(
                    kind, np.asarray(u), np.asarray(alive), where)
                n_cfg += cs.shape[0]
                if findings:
                    break
    return findings, {"configs": n_cfg, "pairs_per_worker": len(pairs)}


# ---------------------------------------------------------------------------
# version ring (serving replica)
# ---------------------------------------------------------------------------

def simulate_replica_model(ops, tau_serve: int) -> list:
    """Reference model of `repro.serve.replica.ParamReplica`'s arithmetic.

    ``ops`` is a sequence of ("publish",) / ("refresh", lag) rounds.  The
    model tracks which version each slot holds and checks: the served slot
    holds exactly ``serving_version``; ``0 <= latest - serving <=
    tau_serve`` at every read; serving never moves backwards.
    """
    cap = tau_serve + 1
    slot_holds = {0: 0}                    # slot -> version last written
    latest = serving = 0
    prev_serving = 0
    violations = []
    for op in ops:
        if op[0] == "publish":
            latest += 1
            slot_holds[latest % cap] = latest
            serving = max(serving, latest - tau_serve)
        else:
            lag = min(op[1], tau_serve)
            serving = max(serving, latest - lag, 0)
        if not 0 <= latest - serving <= tau_serve:
            violations.append(f"staleness {latest - serving} outside "
                              f"[0, {tau_serve}] after {op}")
        if serving < prev_serving:
            violations.append(f"serving moved backwards after {op}")
        prev_serving = serving
        if slot_holds.get(serving % cap) != serving:
            violations.append(
                f"slot {serving % cap} holds version "
                f"{slot_holds.get(serving % cap)} but serving={serving}")
    return violations


def check_replica_ring(tau_serve: int, horizon: int, *,
                       real_runs: int = 512) -> tuple:
    """Enumerate every publish/refresh interleaving x lag schedule up to
    ``horizon`` rounds through the model, then drive the real
    `ParamReplica` (params = the version number itself, so the served value
    IS the served version) on up to ``real_runs`` of them."""
    from repro.serve.replica import ParamReplica
    import jax.numpy as jnp

    where = f"version-ring/tau{tau_serve}/H{horizon}"
    findings: list = []
    round_opts = [("publish",)] + [("refresh", lag)
                                   for lag in range(tau_serve + 1)] \
        + [("refresh", DROPPED)]
    all_runs = list(itertools.product(round_opts, repeat=horizon))
    for ops in all_runs:
        ops = [("refresh", tau_serve) if o == ("refresh", DROPPED) else o
               for o in ops]
        v = simulate_replica_model(ops, tau_serve)
        if v:
            findings.append(_f("version-ring-model", where, v[0]))
            break

    stride = max(1, len(all_runs) // real_runs)
    checked = 0
    for ops in all_runs[::stride]:
        lags = [o[1] for o in ops if o[0] == "refresh"] or [0]
        rep = ParamReplica({"v": jnp.zeros(())}, tau_serve, lags=lags)
        model_serving = 0
        latest = 0
        for op in ops:
            if op[0] == "publish":
                latest += 1
                rep.publish({"v": jnp.full((), float(latest))})
            else:
                rep.refresh()
            got = float(rep.serving_params()["v"])
            if not (latest - tau_serve <= got <= latest and
                    got == rep.serving_version and
                    got >= model_serving):
                findings.append(_f(
                    "version-ring-real", where,
                    f"ParamReplica served version {got} (serving="
                    f"{rep.serving_version}, latest={latest}) after {op}"))
                break
            model_serving = got
        checked += 1
        if any(f.rule == "version-ring-real" for f in findings):
            break
    return findings, {"interleavings": len(all_runs), "real_runs": checked}


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def run(max_p: int = 4, max_tau: int = 3, *, fast: bool = False) -> Report:
    """The full ring-checking pass.  ``fast`` trims the deepest spaces for
    bench smoke runs; the CI lane runs the full bounded model."""
    rep = Report()
    stats: dict = {}

    grid = [(tau, p) for tau in range(0, max_tau + 1)
            for p in (1, 2, max_p) if p <= max_p]
    for tau_max, p in sorted(set(grid)):
        if fast and (tau_max > 2 or p > 2):
            continue
        horizon = max(4, 2 * (tau_max + 1))
        f, s = check_gradient_rings(tau_max, p, horizon,
                                    ground_truth=not fast)
        rep.findings += f
        stats[f"delivery/tau{tau_max}/p{p}"] = s
    for tau_max in (1, 2) if fast else (1, 2, 3):
        rep.findings += check_negative_control(tau_max,
                                               2 * (tau_max + 1))
    for p in (2,) if fast else (2, 3, 4):
        if p > max_p:
            continue
        f, s = check_crash_rejoin_conservation(p, 4)
        rep.findings += f
        stats[f"conservation/p{p}"] = s
    for tau_serve in (0, 1, 2) if fast else (0, 1, 2, 3):
        horizon = 4 if tau_serve >= 2 else 5
        f, s = check_replica_ring(tau_serve, horizon,
                                  real_runs=64 if fast else 512)
        rep.findings += f
        stats[f"version-ring/tau{tau_serve}"] = s
    rep.info["rings"] = stats
    return rep
