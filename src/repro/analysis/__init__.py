"""Static-analysis subsystem: prove hot-path properties without running.

Three passes, one CLI (``python -m repro.analysis``), one checked-in
baseline (``analysis/baseline.json``):

  * `repro.analysis.audit` — trace every public jitted entry point
    (`repro.analysis.entrypoints`) to a jaxpr: collective inventory +
    bytes-on-wire per sync strategy (compressed must beat dense),
    callback/host-transfer detection, donation realization, retrace
    hazards.
  * `repro.analysis.rings` — exhaustive bounded model checker for the
    delivery-ring and version-ring index arithmetic: exactly-once
    delivery, no slot aliasing at capacity tau_max + 1, crash/rejoin
    mass conservation, serving staleness <= tau_serve.
  * `repro.analysis.lint` — AST rules for per-step host syncs, PRNG key
    reuse, np-on-traced, Pallas tile alignment, missing donation.

CI runs all three; only findings whose fingerprint is absent from the
baseline fail the lane (`repro.analysis.findings`).
"""
from repro.analysis.findings import Finding, Report  # noqa: F401
