"""Findings, fingerprints and the checked-in baseline.

Every analysis pass (jaxpr audit, ring checker, AST lint) reports
:class:`Finding`s.  A finding's **fingerprint** is content-addressed —
``sha1(pass | rule | where | detail)`` — deliberately excluding line
numbers, so unrelated edits that shift code never churn the baseline.

The baseline (``analysis/baseline.json`` at the repo root) is the list of
*accepted* findings: pre-existing hazards that are understood and justified
(each entry keeps the human-readable context next to its fingerprint).  CI
fails only on findings whose fingerprint is NOT baselined, so the tool can
be landed with teeth without first burning down every historical wart —
exactly the new-findings-only discipline of `ruff --add-noqa` baselines or
clang-tidy's line filters, but stable against drift.
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    """One violation reported by an analysis pass.

    ``where`` is a stable location id (``path:qualname`` for lint,
    ``entrypoint`` for audits, discipline id for the ring checker) —
    NOT a line number.  ``line`` is carried for display only and excluded
    from the fingerprint.
    """

    pass_name: str          # "lint" | "audit" | "rings"
    rule: str               # e.g. "host-sync-in-step", "donation-missing"
    where: str              # stable location (file:qualname or entrypoint)
    detail: str             # what exactly tripped (stable phrasing)
    line: int = 0           # display only

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.pass_name, self.rule, self.where, self.detail))
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def __str__(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return (f"[{self.pass_name}/{self.rule}] {loc}: {self.detail} "
                f"(fp {self.fingerprint})")


@dataclass
class Report:
    """Aggregated result of one or more passes."""

    findings: list = field(default_factory=list)
    info: dict = field(default_factory=dict)   # pass -> free-form summary

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.info.update(other.info)

    def new_findings(self, baseline: set) -> list:
        return [f for f in self.findings if f.fingerprint not in baseline]

    def to_json(self, baseline: set) -> dict:
        return {
            "findings": [f.to_json() for f in self.findings],
            "new": [f.to_json() for f in self.new_findings(baseline)],
            "baselined": sorted(
                f.fingerprint for f in self.findings
                if f.fingerprint in baseline),
            "info": self.info,
        }


def load_baseline(path: str) -> set:
    """Accepted-finding fingerprints; a missing file is an empty baseline."""
    if not path or not os.path.exists(path):
        return set()
    with open(path) as fh:
        data = json.load(fh)
    return {e["fingerprint"] for e in data.get("accepted", [])}


def _is_real_justification(text) -> bool:
    t = str(text or "").strip()
    return bool(t) and not t.upper().startswith("TODO")


def unjustified_entries(path: str) -> list:
    """Baselined entries whose justification is empty or a TODO
    placeholder.  CI fails on any: an accepted hazard nobody justified is
    a suppression, not a baseline."""
    if not path or not os.path.exists(path):
        return []
    with open(path) as fh:
        data = json.load(fh)
    return [e for e in data.get("accepted", [])
            if not _is_real_justification(e.get("justification"))]


def write_baseline(path: str, findings, justifications=None) -> None:
    """(Re)write the baseline to accept exactly ``findings`` — the
    ``--update-baseline`` flow.  Context rides along for the reviewer;
    ``justification`` strings hand-written into the checked-in file are
    preserved across rewrites (entries are keyed by fingerprint).

    Every entry must carry a real justification: for findings not already
    justified in the checked-in file, supply ``justifications`` —
    fingerprint -> text, with ``"*"`` as a catch-all — or the write is
    refused (no more ``TODO: justify or fix`` placeholders landing in CI).
    """
    justifications = dict(justifications or {})
    old = {}
    if os.path.exists(path):
        with open(path) as fh:
            old = {e["fingerprint"]: e
                   for e in json.load(fh).get("accepted", [])}
    entries = {}
    missing = []
    for f in sorted(findings, key=lambda f: (f.pass_name, f.rule, f.where)):
        if f.fingerprint in entries:
            continue
        just = old.get(f.fingerprint, {}).get("justification", "")
        if not _is_real_justification(just):
            just = justifications.get(f.fingerprint,
                                      justifications.get("*", ""))
        if not _is_real_justification(just):
            missing.append(f)
            continue
        entries[f.fingerprint] = {
            "fingerprint": f.fingerprint,
            "rule": f"{f.pass_name}/{f.rule}",
            "where": f.where,
            "detail": f.detail,
            "justification": str(just).strip(),
        }
    if missing:
        locs = ", ".join(f"{f.where} (fp {f.fingerprint})"
                         for f in missing[:5])
        raise ValueError(
            f"refusing to baseline {len(missing)} finding(s) without a "
            f"real justification: {locs}" + ("..." if len(missing) > 5
                                             else "")
            + " — pass --justify (or per-fingerprint justifications)")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump({"accepted": list(entries.values())}, fh, indent=1,
                  sort_keys=True)
        fh.write("\n")
