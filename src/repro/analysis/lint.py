"""AST lint for jax hot-path hazards.

Static rules over the `src/repro` tree, scoped to **hot-path code**:
functions that are (or build) per-step jitted programs — step/run/prefill/
decode functions, the bodies nested inside ``make_*``/``_build_*``
factories, and anything decorated/wrapped with ``jax.jit``.  A host sync
in a test or a CLI driver is fine; the same call reachable from a per-step
program is a per-step device->host round-trip.

Rules:

  * ``prng-key-reuse`` — a PRNG key variable passed to two or more
    ``jax.random.*`` draws without an intervening ``split``/``fold_in``:
    identical randomness where independent draws were intended.
  * ``np-on-traced`` — ``np.*`` computation applied to hot-path values
    (implicit device->host transfer + an untraced constant baked into the
    program).  Shape/dtype-level helpers (``np.prod`` on shapes, dtype
    constructors, ``np.arange`` of python ints) are whitelisted.
  * ``host-sync-in-step`` — ``float(x)`` / ``int(x)`` / ``.item()`` /
    ``np.asarray(x)`` / ``jax.device_get`` inside hot-path code: a
    blocking transfer per step.
  * ``pallas-tile-misalign`` — integer tile/block constants in Pallas
    kernel call sites that are not multiples of the 128-wide lane dim
    (the TPU/Mosaic layout unit; misaligned tiles silently re-layout).
  * ``missing-donation`` — a ``jax.jit`` call site whose positional
    target is a step/train/decode function but that declares no
    ``donate_argnums``: the params/optimizer buffers are copied every
    step instead of reused.

Every finding is keyed ``path:qualname`` (line numbers carried for
display only), so the checked-in baseline survives unrelated edits.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding, Report

LANE = 128          # mosaic lane width: last-dim tiles must be multiples
HOT_NAME_HINTS = ("step", "run", "body", "prefill", "decode", "train",
                  "kernel", "fwd", "bwd", "loop")
FACTORY_HINTS = ("make_", "_build_", "build_")
# host-sync callables when applied to traced values (bool() is excluded:
# it is overwhelmingly applied to compile-time python values like axis sets)
HOST_SYNC_CALLS = {"float", "int"}
HOST_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
# np.* helpers that are shape/config-level, not data-path
NP_WHITELIST = {"prod", "dtype", "int32", "int64", "float32", "float64",
                "bool_", "uint32", "shape", "ndim", "iinfo", "finfo",
                "ceil", "floor", "log2", "sqrt", "maximum", "minimum"}

DEFAULT_ROOTS = ("src/repro",)
SKIP_DIRS = {"analysis", "__pycache__"}


def _f(rule, where, detail, line=0):
    return Finding(pass_name="lint", rule=rule, where=where, detail=detail,
                   line=line)


def is_hot_name(name: str) -> bool:
    low = name.lower()
    return any(h in low for h in HOT_NAME_HINTS)


def is_factory_name(name: str) -> bool:
    return any(name.startswith(h) for h in FACTORY_HINTS)


# ---------------------------------------------------------------------------
# call-shape helpers
# ---------------------------------------------------------------------------

def _dotted(node) -> str:
    """'jax.random.split' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _arg_names(call: ast.Call) -> list:
    return [a.id for a in call.args if isinstance(a, ast.Name)]


# ---------------------------------------------------------------------------
# per-function rule visitors
# ---------------------------------------------------------------------------

def check_prng_reuse(fn: ast.FunctionDef, where: str) -> list:
    """Key names consumed by >= 2 jax.random draws with no split between.

    Linear scan in source order per key name: a ``jax.random.<draw>(key)``
    marks the key used; a later draw of the same un-renewed key is the
    finding; ``split``/``fold_in`` (or any reassignment of the name)
    renews it.
    """
    findings = []
    used: dict = {}
    flagged: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        used.pop(n.id, None)
        if not isinstance(node, ast.Call):
            continue
        dn = _dotted(node.func)
        if not dn.endswith(tuple(
                ".random." + d for d in
                ("normal", "uniform", "randint", "bernoulli", "categorical",
                 "permutation", "choice", "gumbel", "truncated_normal"))) \
                and not (dn.startswith(("jax.random.", "jrandom.", "jr."))
                         and not dn.endswith(("split", "fold_in",
                                              "PRNGKey", "key"))):
            if dn.endswith(("split", "fold_in")):
                for name in _arg_names(node):
                    used.pop(name, None)
            continue
        for name in _arg_names(node)[:1]:       # key is arg 0 by convention
            if name in used and (where, name) not in flagged:
                findings.append(_f(
                    "prng-key-reuse", where,
                    f"key '{name}' consumed by two draws without split",
                    line=node.lineno))
                flagged.add((where, name))
            used[name] = node.lineno
    return findings


def check_host_sync(fn: ast.FunctionDef, where: str) -> list:
    findings = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        dn = _dotted(node.func)
        detail = None
        if isinstance(node.func, ast.Name) \
                and node.func.id in HOST_SYNC_CALLS and node.args \
                and not isinstance(node.args[0], ast.Constant):
            detail = f"{node.func.id}(...) forces a device->host sync"
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in HOST_SYNC_ATTRS:
            detail = f".{node.func.attr}() forces a device->host sync"
        elif dn in ("np.asarray", "numpy.asarray", "np.array",
                    "numpy.array", "jax.device_get"):
            detail = f"{dn}(...) pulls a device value to host"
        if detail:
            findings.append(_f("host-sync-in-step", where, detail,
                               line=node.lineno))
    return findings


def check_np_on_traced(fn: ast.FunctionDef, where: str) -> list:
    """np.<fn>(x) on non-constant args inside hot-path code."""
    findings = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        dn = _dotted(node.func)
        if not dn.startswith(("np.", "numpy.")):
            continue
        tail = dn.split(".", 1)[1]
        if tail.split(".")[0] in NP_WHITELIST \
                or tail in ("asarray", "array"):  # host-sync rule's turf
            continue
        if node.args and not all(
                isinstance(a, (ast.Constant, ast.Tuple)) for a in node.args):
            findings.append(_f(
                "np-on-traced", where,
                f"{dn}(...) on a non-constant inside hot-path code — "
                f"untraced host math", line=node.lineno))
    return findings


def check_pallas_tiles(tree: ast.Module, path: str) -> list:
    """Pallas call sites: block/tile keyword constants must be multiples
    of the 128 lane width (last dim)."""
    findings = []
    src_is_pallas = any(
        isinstance(n, (ast.Import, ast.ImportFrom))
        and "pallas" in ast.dump(n) for n in ast.walk(tree))
    if not src_is_pallas:
        return findings
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg is None or not any(
                    h in kw.arg for h in ("block", "tile", "lane")):
                continue
            vals = []
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, int):
                vals = [kw.value.value]
            elif isinstance(kw.value, ast.Tuple):
                elts = [e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
                vals = elts[-1:]                 # lane dim = last
            for v in vals:
                if v >= 8 and v % LANE != 0:
                    findings.append(_f(
                        "pallas-tile-misalign", f"{path}:{kw.arg}",
                        f"tile constant {v} is not a multiple of the "
                        f"{LANE}-wide lane dim", line=node.lineno))
    return findings


def check_missing_donation(tree: ast.Module, path: str) -> list:
    """jax.jit(step_like_fn) with no donate_argnums at src jit sites."""
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) not in ("jax.jit", "jit"):
            continue
        if any(kw.arg == "donate_argnums" for kw in node.keywords):
            continue
        target = ""
        if node.args:
            a0 = node.args[0]
            if isinstance(a0, ast.Name):
                target = a0.id
            elif isinstance(a0, ast.Call):
                target = _dotted(a0.func).rsplit(".", 1)[-1]
        if target.startswith(("make_train", "make_elastic", "make_async",
                              "make_paged")) or target in (
                "step", "train_step", "local_step"):
            findings.append(_f(
                "missing-donation", f"{path}:{target or '<lambda>'}",
                "jit of a step function without donate_argnums — params/"
                "state buffers are copied every step",
                line=node.lineno))
    return findings


# ---------------------------------------------------------------------------
# hot-path scoping + file driver
# ---------------------------------------------------------------------------

def hot_functions(tree: ast.Module):
    """(qualname, FunctionDef) for hot-path functions: step-named
    functions anywhere, and every function nested inside a factory.
    A factory itself (``make_*``/``_build_*``) is NOT scanned directly —
    its body runs once at build time; only the closures it returns are
    per-step code."""
    out = []

    def visit(node, prefix, inside_factory):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                factory = inside_factory or is_factory_name(child.name)
                if not is_factory_name(child.name) and (
                        is_hot_name(child.name)
                        or (inside_factory
                            and not child.name.startswith("_init"))):
                    out.append((qual, child))
                visit(child, qual + ".", factory)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", inside_factory)
            else:
                visit(child, prefix, inside_factory)

    visit(tree, "", False)
    # dedupe nested hits (a hot fn inside a hot fn reports once, outermost)
    seen: set = set()
    uniq = []
    for qual, fn in out:
        if any(qual != q and qual.startswith(q + ".") for q, _ in out):
            continue
        if qual not in seen:
            seen.add(qual)
            uniq.append((qual, fn))
    return uniq


def lint_file(path: str, rel: str) -> list:
    with open(path) as fh:
        src = fh.read()
    tree = ast.parse(src, filename=path)
    findings = []
    findings += check_pallas_tiles(tree, rel)
    findings += check_missing_donation(tree, rel)
    for qual, fn in hot_functions(tree):
        where = f"{rel}:{qual}"
        findings += check_prng_reuse(fn, where)
        findings += check_host_sync(fn, where)
        findings += check_np_on_traced(fn, where)
    return findings


def run(roots=DEFAULT_ROOTS, repo_root: str | None = None) -> Report:
    rep = Report()
    base = repo_root or os.getcwd()
    n_files = 0
    for root in roots:
        top = os.path.join(base, root)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d not in SKIP_DIRS]
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, base)
                try:
                    rep.findings += lint_file(path, rel)
                except SyntaxError as e:
                    rep.findings.append(_f("unparseable", rel, str(e)))
                n_files += 1
    rep.info["lint"] = {"files": n_files,
                        "findings": len(rep.findings)}
    return rep
