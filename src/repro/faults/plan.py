"""`FaultPlan`: a seeded, replayable schedule of injected faults.

The paper treats faults as *relaxations* of consistency — stale, dropped
and crashed gradients are all legal as long as Def. 1's bound holds.  A
`FaultPlan` is the runtime counterpart of the simulator's oblivious
adversary: a plain list of ``(step, kind, ...)`` events drawn up-front
(either hand-written or from :meth:`FaultPlan.random` with a seed), JSON
round-trippable so the *same* faults can be replayed against the live
system and against the emulated oracle (`benchmarks/bench_faults.py`
gates on the two trajectories matching).

Event kinds:

  ==============  =====================================================
  ``kill``        SIGKILL the training process after step ``step``
                  (fires only on attempt ``on_attempt`` so a supervisor
                  restart does not re-trigger it forever)
  ``grad_poison`` the step-``step`` batch produces NaN gradients
                  (``param`` > 0 poisons with +inf instead)
  ``ckpt_io``     the checkpoint save at step ``step`` raises OSError
  ``crash``       worker ``worker`` stops delivering (DROPPED tau rows)
                  from ``step`` for ``duration`` steps (0 = forever)
  ``rejoin``      worker ``worker`` resumes delivering from ``step``
  ``delay``       worker ``worker`` straggles at ``tau_max`` for
                  ``duration`` steps
  ``drop``        worker ``worker``'s deposits are dropped for
                  ``duration`` steps
  ``logit_poison``  serve: NaN-poison an active request's KV at tick
                  ``step`` (quarantine path)
  ``page_exhaust``  serve: grab ``param`` pages from the pool at tick
                  ``step`` for ``duration`` ticks (backpressure path)
  ==============  =====================================================

Tau-shaped kinds (``crash``/``rejoin``/``delay``/``drop``) are applied to
a pre-drawn `repro.core.delivery.make_tau_schedule` table with
:meth:`FaultPlan.apply_to_taus` — the async engine then runs them with no
new code, and the delivery-ring conservation tests keep holding because
the overrides only ever write legal values (``[0, tau_max]`` or DROPPED).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core.delivery import DROPPED

#: kinds that rewrite the async engine's tau table
TAU_KINDS = ("crash", "rejoin", "delay", "drop")
#: kinds the serving-side injector understands
SERVE_KINDS = ("logit_poison", "page_exhaust")
FAULT_KINDS = ("kill", "grad_poison", "ckpt_io") + TAU_KINDS + SERVE_KINDS


@dataclass(frozen=True)
class FaultEvent:
    step: int                     # training step / serve tick it fires at
    kind: str                     # one of FAULT_KINDS
    worker: int = -1              # TAU_KINDS: which worker (-1 = last)
    duration: int = 1             # TAU_KINDS/page_exhaust: steps it lasts
    param: float = 0.0            # kind-specific knob (see module doc)
    on_attempt: int = 0           # kill: only fire on this launch attempt

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.duration < 0:
            raise ValueError(f"duration must be >= 0, got {self.duration}")


@dataclass(frozen=True)
class FaultPlan:
    events: tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            e if isinstance(e, FaultEvent) else FaultEvent(**e)
            for e in self.events))

    # -- queries -----------------------------------------------------------
    def at(self, step: int, kind: str | None = None) -> list[FaultEvent]:
        return [e for e in self.events
                if e.step == step and (kind is None or e.kind == kind)]

    def kinds(self) -> set:
        return {e.kind for e in self.events}

    @property
    def has_poison(self) -> bool:
        return any(e.kind == "grad_poison" for e in self.events)

    @property
    def has_tau_events(self) -> bool:
        return any(e.kind in TAU_KINDS for e in self.events)

    @property
    def max_step(self) -> int:
        return max((e.step for e in self.events), default=0)

    # -- (de)serialization (replayability) ---------------------------------
    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "events": [asdict(e) for e in self.events]},
                          indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        return cls(events=tuple(FaultEvent(**e) for e in obj["events"]),
                   seed=int(obj.get("seed", 0)))

    @classmethod
    def load(cls, path_or_json: str) -> "FaultPlan":
        """Accepts a file path or inline JSON (starts with ``{``)."""
        text = path_or_json
        if not path_or_json.lstrip().startswith("{"):
            with open(path_or_json) as f:
                text = f.read()
        return cls.from_json(text)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    # -- generation --------------------------------------------------------
    @classmethod
    def random(cls, seed: int, steps: int, workers: int, *,
               n_events: int = 4, kinds=TAU_KINDS + ("grad_poison",),
               tau_max: int = 4) -> "FaultPlan":
        """Seeded random plan: ``n_events`` events over ``steps`` steps.
        The draw is a pure function of the arguments, so the same seed
        replays the same faults anywhere."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            events.append(FaultEvent(
                step=int(rng.integers(0, max(steps, 1))), kind=kind,
                worker=int(rng.integers(0, max(workers, 1))),
                duration=int(rng.integers(1, max(steps // 4, 2))),
                param=float(rng.uniform())))
        return cls(events=tuple(sorted(events, key=lambda e: e.step)),
                   seed=seed)

    # -- tau-table rewriting (crash / rejoin / delay / drop) ---------------
    def apply_to_taus(self, taus: np.ndarray, tau_max: int) -> np.ndarray:
        """Rewrite a (T, p) delay table per this plan's TAU_KINDS events.

        ``crash`` marks the worker dead from ``step`` (for ``duration``
        steps; 0 = until a later ``rejoin``), ``rejoin`` revives it (the
        original scheduled delays resume), ``delay`` pins it at
        ``tau_max``, ``drop`` discards its deposits for the window.
        Events apply in step order, so crash→rejoin windows compose.
        """
        taus = np.array(taus, np.int32, copy=True)
        t_len, p = taus.shape
        alive = np.ones_like(taus, bool)
        for ev in sorted((e for e in self.events if e.kind in TAU_KINDS),
                         key=lambda e: e.step):
            w = ev.worker % p
            s = min(ev.step, t_len)
            end = t_len if ev.duration == 0 else min(s + ev.duration, t_len)
            if ev.kind == "crash":
                alive[s:end, w] = False
            elif ev.kind == "rejoin":
                alive[s:, w] = True
            elif ev.kind == "delay":
                taus[s:end, w] = np.where(taus[s:end, w] == DROPPED,
                                          DROPPED, tau_max)
            elif ev.kind == "drop":
                alive[s:end, w] = False
        return np.where(alive, taus, DROPPED).astype(np.int32)


def _main():
    """Tiny plan-authoring CLI (see README ``--fault-plan`` usage):

      python -m repro.faults.plan --out plan.json --kill-at 6 \\
          --crash 1@4:0 --rejoin 1@9 --poison-at 3 --ckpt-io-at 8
    """
    import argparse

    ap = argparse.ArgumentParser(description=_main.__doc__)
    ap.add_argument("--out", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-at", type=int, action="append", default=[])
    ap.add_argument("--kill-attempt", type=int, default=0)
    ap.add_argument("--poison-at", type=int, action="append", default=[])
    ap.add_argument("--ckpt-io-at", type=int, action="append", default=[])
    ap.add_argument("--crash", action="append", default=[],
                    metavar="W@S[:D]", help="worker W crashes at step S "
                    "for D steps (D=0 or omitted: until rejoin)")
    ap.add_argument("--rejoin", action="append", default=[], metavar="W@S")
    ap.add_argument("--delay", action="append", default=[],
                    metavar="W@S[:D]")
    ap.add_argument("--drop", action="append", default=[], metavar="W@S[:D]")
    args = ap.parse_args()

    def windowed(spec: str, kind: str) -> FaultEvent:
        w, rest = spec.split("@")
        s, _, d = rest.partition(":")
        return FaultEvent(step=int(s), kind=kind, worker=int(w),
                          duration=int(d) if d else 0)

    events = [FaultEvent(step=s, kind="kill", on_attempt=args.kill_attempt)
              for s in args.kill_at]
    events += [FaultEvent(step=s, kind="grad_poison")
               for s in args.poison_at]
    events += [FaultEvent(step=s, kind="ckpt_io") for s in args.ckpt_io_at]
    for flag, kind in (("crash", "crash"), ("rejoin", "rejoin"),
                       ("delay", "delay"), ("drop", "drop")):
        events += [windowed(spec, kind) for spec in getattr(args, flag)]
    plan = FaultPlan(events=tuple(sorted(events, key=lambda e: e.step)),
                     seed=args.seed)
    if args.out:
        plan.save(args.out)
        print(f"wrote {len(plan.events)} events to {args.out}")
    else:
        print(plan.to_json())


if __name__ == "__main__":
    _main()
