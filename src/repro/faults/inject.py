"""Host-side fault injectors: drive a `FaultPlan` through the launchers.

`TrainFaultInjector` sits in `launch.train`'s step loop: it poisons batches
(via the ``loss_scale`` channel `repro.dist.train.loss_fn` multiplies in),
raises scheduled checkpoint-IO errors, and SIGKILLs the process at kill
events — but only on the event's designated launch attempt, so a
supervisor restart replays the surviving schedule instead of dying on the
same step forever.

`ServeFaultInjector` sits in `ContinuousScheduler.step` (the ``on_tick``
hook): it NaN-poisons an active request's KV (exercising the quarantine
path) and temporarily exhausts the page pool (exercising retry-after
backpressure).  Both injectors are pure functions of (plan, attempt/tick),
so a seeded plan replays identically.
"""
from __future__ import annotations

import os
import signal

from repro.faults.plan import FaultPlan


class TrainFaultInjector:
    """Applies a plan's training-side events inside `launch.train`."""

    def __init__(self, plan: FaultPlan, attempt: int = 0):
        self.plan = plan
        self.attempt = attempt
        self.poisoned_steps = 0
        self.ckpt_errors = 0

    @property
    def has_poison(self) -> bool:
        return self.plan.has_poison

    def loss_scale(self, step: int) -> float:
        """1.0 normally; NaN (or +inf when ``param > 0``) on a
        ``grad_poison`` step — scaling the loss poisons every gradient
        leaf without touching the model code."""
        evs = self.plan.at(step, "grad_poison")
        if not evs:
            return 1.0
        self.poisoned_steps += 1
        return float("inf") if evs[0].param > 0 else float("nan")

    def check_ckpt_io(self, step: int) -> None:
        """Raise the scheduled checkpoint-IO error (callers catch OSError,
        warn and keep training — checkpointing is best-effort)."""
        if self.plan.at(step, "ckpt_io"):
            self.ckpt_errors += 1
            raise OSError(f"injected checkpoint IO failure at step {step}")

    def maybe_kill(self, step: int) -> None:
        """SIGKILL after step ``step`` if a kill event for this attempt is
        scheduled.  SIGKILL (not an exception) on purpose: no atexit, no
        flushing — the hardest crash the supervisor must survive."""
        for ev in self.plan.at(step, "kill"):
            if ev.on_attempt == self.attempt:
                print(f"fault: SIGKILL at step {step} "
                      f"(attempt {self.attempt})", flush=True)
                os.kill(os.getpid(), signal.SIGKILL)


class ServeFaultInjector:
    """Applies a plan's serve-side events through the scheduler's
    ``on_tick`` hook (called once per decode tick, before admission)."""

    def __init__(self, plan: FaultPlan, engine):
        self.plan = plan
        self.engine = engine
        self.poisoned = 0
        self.exhausted = 0
        self._holds: list = []        # (release_tick, hold_rid)

    def on_tick(self, sched) -> None:
        tick = sched.clock
        # release expired page holds first so capacity comes back
        keep = []
        for release, rid in self._holds:
            if tick >= release:
                self.engine.alloc.free(rid)
            else:
                keep.append((release, rid))
        self._holds = keep

        for ev in self.plan.at(tick, "page_exhaust"):
            want = int(ev.param) if ev.param > 0 else self.engine.alloc.n_free
            n = min(want, self.engine.alloc.n_free)
            if n > 0:
                rid = f"__fault_{tick}_{self.exhausted}__"
                self.engine.alloc.alloc(rid, n)
                self._holds.append((tick + max(ev.duration, 1), rid))
                self.exhausted += 1

        if self.plan.at(tick, "logit_poison") and sched._live:
            rid = min(sched._live)    # deterministic victim
            self.engine.poison_kv(rid)
            self.poisoned += 1

    def release_all(self) -> None:
        """Free any page holds still live (end-of-run cleanup)."""
        for _, rid in self._holds:
            self.engine.alloc.free(rid)
        self._holds = []
