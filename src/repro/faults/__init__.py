"""Deterministic fault injection + supervision for training and serving.

`repro.faults.plan` is the seeded, replayable `FaultPlan` DSL (worker
crash/rejoin, NaN/Inf gradient poisoning, delayed/dropped ring deposits,
checkpoint-IO errors, SIGKILLs, serve-side logit poisoning and page-pool
exhaustion); `repro.faults.inject` holds the host-side injectors that
drive a plan through `launch.train` and `launch.serve`.  The supervisor
that restarts killed runs lives in `repro.launch.supervisor`.
"""
from repro.faults.plan import (FAULT_KINDS, SERVE_KINDS, TAU_KINDS,
                               FaultEvent, FaultPlan)
from repro.faults.inject import ServeFaultInjector, TrainFaultInjector

__all__ = [
    "FAULT_KINDS", "SERVE_KINDS", "TAU_KINDS", "FaultEvent", "FaultPlan",
    "ServeFaultInjector", "TrainFaultInjector",
]
