"""Cluster co-simulation CLI: rank (strategy, tau_max, compressor)
candidates by *time*-to-loss on a concrete cluster shape.

Joins the discrete-event cluster model (`repro.cluster`) with the
convergence simulator (`core.sim_engine.simulate_grid`): the cluster
model prices each candidate's per-step wall-clock from its bytes-on-wire
(golden collective inventory) and emits the measured ``tau(t, worker)``
table; the convergence run replays exactly that staleness trace, so
steps-to-loss and time-to-loss come from the *same* execution history.

Usage:
  python -m repro.launch.cosim --cluster straggler_heavy --p 4 \
      --out experiments/cosim_straggler.json
  python -m repro.launch.cosim --cluster path/to/spec.json

``--cluster`` accepts a preset name (see ``repro.cluster.PRESETS``) or a
path to a ClusterSpec JSON file (`ClusterSpec.save` round-trips).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.cluster import (DEFAULT_CANDIDATES, PRESETS, ClusterSpec, preset,
                           rank_candidates, winners)


def load_cluster(name_or_path: str, p: int, steps: int) -> ClusterSpec:
    if os.path.exists(name_or_path):
        return ClusterSpec.load(name_or_path)
    if name_or_path in PRESETS:
        return preset(name_or_path, p=p, steps=steps)
    raise SystemExit(
        f"unknown cluster {name_or_path!r}: not a file, not one of "
        f"{', '.join(PRESETS)}")


def main() -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.cosim",
        description="rank sync strategies by time-to-loss on a cluster "
                    "shape (discrete-event model x convergence sim)")
    ap.add_argument("--cluster", default="straggler_heavy",
                    help=f"preset ({', '.join(PRESETS)}) or ClusterSpec "
                         f"JSON path")
    ap.add_argument("--p", type=int, default=4,
                    help="workers (presets only; a spec file fixes p)")
    ap.add_argument("--steps", type=int, default=600,
                    help="event-loop horizon (learner steps)")
    ap.add_argument("--flops-per-step", type=float, default=4e8)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--target-frac", type=float, default=0.01,
                    help="loss target as a fraction of the initial loss")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated convergence seeds (averaged)")
    ap.add_argument("--out", default="",
                    help="write the ranking JSON here")
    args = ap.parse_args()

    spec = load_cluster(args.cluster, args.p, args.steps)
    seeds = tuple(int(s) for s in args.seeds.split(",") if s)
    results, runs = rank_candidates(
        spec, t_len=args.steps, flops_per_step=args.flops_per_step,
        alpha=args.alpha, target_frac=args.target_frac, seeds=seeds or (0,))
    win = winners(results)

    cand_by_name = {c.name: c for c in DEFAULT_CANDIDATES}
    print(f"cluster {spec.name} (p={spec.p}, {len(spec.events)} events), "
          f"{args.steps} steps, target {args.target_frac:.3g}x initial loss")
    print(f"{'candidate':<26} {'steps':>6} {'time_s':>10} {'step_ms':>9} "
          f"{'wire_B':>10} {'drop':>5}")
    for r in sorted(results, key=lambda r: r.time_to_loss):
        steps = ("-" if not (r.steps_to_loss < float("inf"))
                 else str(int(r.steps_to_loss)))
        marks = "".join(m for m, k in (("S", "steps"), ("T", "time"))
                        if win[k] == r.candidate)
        print(f"{r.candidate:<26} {steps:>6} {r.time_to_loss:>10.2f} "
              f"{r.step_s * 1e3:>9.2f} {r.wire_bytes:>10.0f} "
              f"{r.dropped:>5d} {marks}")
    print(f"winner by steps-to-loss: {win['steps']}")
    print(f"winner by  time-to-loss: {win['time']}")
    if win["steps"] != win["time"]:
        print("-> the rankings DISAGREE: step counts alone would pick the "
              "wrong strategy for this cluster shape")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        payload = {
            "cluster": json.loads(spec.to_json()),
            "steps": args.steps,
            "flops_per_step": args.flops_per_step,
            "alpha": args.alpha,
            "target_frac": args.target_frac,
            "winners": win,
            "candidates": [{
                "name": r.candidate,
                "strategy": cand_by_name[r.candidate].strategy,
                "sim_kind": cand_by_name[r.candidate].sim_kind,
                "tau_max": cand_by_name[r.candidate].tau_max,
                "steps_to_loss": (r.steps_to_loss
                                  if r.steps_to_loss < float("inf")
                                  else None),
                "time_to_loss_s": (r.time_to_loss
                                   if r.time_to_loss < float("inf")
                                   else None),
                "step_s": r.step_s,
                "wire_bytes": r.wire_bytes,
                "tau_histogram": {str(k): v
                                  for k, v in r.tau_histogram.items()},
                "dropped": r.dropped,
            } for r in results],
        }
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1)
            fh.write("\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
