"""Serving driver: prefill a batch of prompts, then decode with batched
one-token steps (the same serve_step the decode dry-run shapes lower).

  python -m repro.launch.serve --arch qwen3-1.7b-smoke --prompt-len 32 \
      --gen 16 --batch 4
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.dist.train import make_decode_step, make_prefill_step
    from repro.models import transformer as TF
    from repro.models.params import init_params

    cfg = get_config(args.arch)
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen

    batch = synthetic_batch(cfg, args.batch, args.prompt_len, args.seed)
    batch.pop("labels")
    prefill = jax.jit(make_prefill_step(cfg, max_len, flags))
    decode = jax.jit(make_decode_step(cfg, flags), donate_argnums=(1,))

    tok, cache = prefill(params, batch)
    out = [np.asarray(tok)]
    for _ in range(args.gen - 1):
        tok, cache = decode(params, cache, tok[:, None])
        out.append(np.asarray(tok))
    gen = np.stack(out, axis=1)
    for i, row in enumerate(gen):
        print(f"seq {i}: {row.tolist()}")
    return gen


if __name__ == "__main__":
    main()
