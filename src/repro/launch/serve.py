"""Serving driver: legacy batched loop or the continuous-batching engine.

  # legacy loop (the parity oracle): one static batch, greedy decode
  python -m repro.launch.serve --arch qwen3-1.7b-smoke --prompt-len 32 \
      --gen 16 --batch 4

  # continuous batching on the paged KV cache, mixed-length requests
  python -m repro.launch.serve --arch qwen3-1.7b-smoke --engine continuous \
      --prompt-lens 8,16,24,8 --gen 16 --devices 2

The loop engine keeps every step's tokens on device and fetches ONCE at the
end (`jnp.stack` then a single ``np.asarray``) — the old per-token
``np.asarray`` blocked dispatch pipelining on exactly the workload serving
cares about.  ``--temperature/--top-k`` switch both engines from greedy to
sampled decoding (`repro.serve.sampling.SampleConfig`).
"""
import argparse
import os


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-smoke")
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "continuous"])
    ap.add_argument("--batch", type=int, default=4,
                    help="loop: batch size; continuous: request slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prompt-lens", default="",
                    help="continuous: comma list of per-request prompt "
                         "lengths (default: --batch x --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-plan", default="",
                    help="continuous engine: repro.faults plan (path or "
                         "inline JSON) — logit_poison/page_exhaust events "
                         "drive the quarantine/backpressure paths")
    return ap.parse_args()


def _run_loop(args, cfg, flags, params, sample):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.pipeline import synthetic_batch
    from repro.dist.train import make_decode_step, make_prefill_step

    max_len = args.prompt_len + args.gen
    batch = synthetic_batch(cfg, args.batch, args.prompt_len, args.seed)
    batch.pop("labels")
    prefill = jax.jit(make_prefill_step(cfg, max_len, flags, sample))
    decode = jax.jit(make_decode_step(cfg, flags, sample),
                     donate_argnums=(1,))

    key = jax.random.PRNGKey(args.seed + 1)
    sampled = sample is not None and not sample.is_greedy

    def split():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    tok, cache = (prefill(params, batch, split()) if sampled
                  else prefill(params, batch))
    out = [tok]                     # device arrays; fetched once at the end
    for _ in range(args.gen - 1):
        tok, cache = (decode(params, cache, tok[:, None], split()) if sampled
                      else decode(params, cache, tok[:, None]))
        out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))     # ONE host sync


def _run_continuous(args, cfg, flags, params, sample):
    import numpy as np

    from repro.serve import (ContinuousScheduler, PagedCacheConfig, Request,
                             SampleConfig, StepEngine)

    if args.prompt_lens:
        lens = [int(s) for s in args.prompt_lens.split(",")]
    else:
        lens = [args.prompt_len] * args.batch
    ps = args.page_size
    per_req = -(-(max(lens) + args.gen) // ps)
    pcfg = PagedCacheConfig(
        page_size=ps, max_requests=min(args.batch, len(lens)),
        max_pages_per_seq=per_req,
        num_pages=sum(-(-(s + args.gen) // ps) for s in lens))
    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    plan = injector = None
    if args.fault_plan:
        from repro.faults import FaultPlan, ServeFaultInjector
        plan = FaultPlan.load(args.fault_plan)
    engine = StepEngine(cfg, params, pcfg, flags,
                        sample=sample or SampleConfig(),
                        mesh=mesh, seed=args.seed,
                        check_finite=plan is not None
                        and "logit_poison" in plan.kinds())
    if plan is not None:
        injector = ServeFaultInjector(plan, engine)
    sched = ContinuousScheduler(
        engine, queue_limit=4 * len(lens),
        quarantine=plan is not None,
        on_tick=injector.on_tick if injector else None)
    rng = np.random.default_rng(args.seed)
    trace = [Request(rid=i, max_new=args.gen, arrival=0,
                     prompt=rng.integers(0, cfg.vocab_size, size=s,
                                         dtype=np.int32))
             for i, s in enumerate(lens)]
    toks = sched.run(trace)
    if injector is not None:
        injector.release_all()
    engine.alloc.check()
    st = sched.stats()
    print(f"continuous: {len(lens)} requests in {sched.clock} steps, "
          f"p50={st['p50']:.0f} p99={st['p99']:.0f} latency steps, "
          f"rejected={sched.rejected} "
          f"rejected_frac={st['rejected_frac']:.3f} "
          f"quarantined={st['quarantined']} failed={st['failed']}")
    return [toks.get(i, np.zeros((0,), np.int32)) for i in range(len(lens))]


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax

    from repro.configs import get_config
    from repro.models import transformer as TF
    from repro.models.params import init_params
    from repro.serve.sampling import SampleConfig

    cfg = get_config(args.arch)
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(args.seed))
    sample = (SampleConfig(temperature=args.temperature, top_k=args.top_k)
              if args.temperature > 0 else None)

    if args.engine == "loop":
        gen = _run_loop(args, cfg, flags, params, sample)
    else:
        gen = _run_continuous(args, cfg, flags, params, sample)
    for i, seq_tokens in enumerate(gen):
        print(f"seq {i}: {list(map(int, seq_tokens))}")
    return gen


if __name__ == "__main__":
    main()
