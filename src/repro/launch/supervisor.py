"""Supervised training: watchdog + restart-from-checkpoint wrapper.

Wraps a `repro.launch.train` run in a child process and keeps it alive
through real faults:

  * **heartbeat watchdog** — the trainer's step logs are the heartbeat; if
    no output arrives for ``--heartbeat`` seconds the child is presumed
    wedged and SIGKILLed (then treated like any other crash).
  * **restart with backoff** — a nonzero/killed exit restarts the run with
    seeded-jittered exponential backoff, up to ``--max-restarts`` times.
    The child resumes itself from the latest *valid* checkpoint
    (`repro.checkpoint.latest_step` skips torn ones), so recovery needs no
    supervisor-side state beyond the attempt counter.
  * **fault-plan threading** — ``--fault-plan`` is forwarded to the child
    along with ``--fault-attempt N``, so a plan's ``kill`` events fire only
    on their designated attempt (otherwise a scheduled SIGKILL would
    re-fire forever: every resume replays the steps since the last
    checkpoint, including the kill step).

Usage (everything after ``--`` goes to `repro.launch.train`):

  python -m repro.launch.supervisor --max-restarts 3 --fault-plan plan.json \\
      -- --arch qwen3-1.7b-smoke --steps 24 --sync async --tau-max 2 \\
         --ckpt-dir /tmp/ckpt --ckpt-every 4

Exit code: the child's final exit code (0 on success), or 1 when the
restart budget is exhausted.
"""
from __future__ import annotations

import argparse
import queue
import subprocess
import sys
import threading
import time

import numpy as np


def _parse(argv=None):
    ap = argparse.ArgumentParser(
        description="watchdog/restart supervisor for repro.launch.train")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restarts after the first attempt (bounded retries)")
    ap.add_argument("--backoff", type=float, default=0.5,
                    help="base backoff seconds (doubles per restart)")
    ap.add_argument("--heartbeat", type=float, default=300.0,
                    help="seconds without child output before SIGKILL")
    ap.add_argument("--fault-plan", default="",
                    help="forwarded to the child with --fault-attempt")
    ap.add_argument("--seed", type=int, default=0,
                    help="backoff jitter RNG (deterministic restarts)")
    ap.add_argument("train_args", nargs=argparse.REMAINDER,
                    help="-- then repro.launch.train arguments")
    return ap.parse_args(argv)


def _pump(proc, out_q):
    """Reader thread: child stdout lines -> queue (the heartbeat source)."""
    for line in proc.stdout:
        out_q.put(line)
    out_q.put(None)                   # EOF marker


def supervise(train_args, *, max_restarts: int = 3, backoff: float = 0.5,
              heartbeat: float = 300.0, fault_plan: str = "",
              seed: int = 0, echo=print) -> int:
    """Run `repro.launch.train` under supervision; returns the exit code."""
    rng = np.random.default_rng(seed)
    attempt = 0
    while True:
        cmd = [sys.executable, "-m", "repro.launch.train", *train_args]
        if fault_plan:
            cmd += ["--fault-plan", fault_plan,
                    "--fault-attempt", str(attempt)]
        echo(f"[supervisor] attempt {attempt}: {' '.join(cmd)}", flush=True)
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
        out_q: queue.Queue = queue.Queue()
        threading.Thread(target=_pump, args=(proc, out_q),
                         daemon=True).start()
        watchdog_fired = False
        while True:
            try:
                line = out_q.get(timeout=heartbeat)
            except queue.Empty:
                echo(f"[supervisor] no heartbeat for {heartbeat:.0f}s — "
                     f"killing wedged child", flush=True)
                proc.kill()
                watchdog_fired = True
                break
            if line is None:
                break
            echo(line.rstrip("\n"), flush=True)
        rc = proc.wait()
        if rc == 0 and not watchdog_fired:
            echo(f"[supervisor] child completed on attempt {attempt}",
                 flush=True)
            return 0
        echo(f"[supervisor] child exited rc={rc}"
             f"{' (watchdog)' if watchdog_fired else ''}", flush=True)
        if attempt >= max_restarts:
            echo(f"[supervisor] restart budget exhausted "
                 f"({max_restarts} restarts)", flush=True)
            return 1
        # jittered exponential backoff: deterministic given --seed
        delay = backoff * (2 ** attempt) * (1.0 + 0.25 * rng.random())
        echo(f"[supervisor] restarting in {delay:.2f}s", flush=True)
        time.sleep(delay)
        attempt += 1


def main(argv=None) -> int:
    args = _parse(argv)
    train_args = args.train_args
    if train_args and train_args[0] == "--":
        train_args = train_args[1:]
    if not train_args:
        raise SystemExit("no train args: supervisor -- <launch.train args>")
    return supervise(train_args, max_restarts=args.max_restarts,
                     backoff=args.backoff, heartbeat=args.heartbeat,
                     fault_plan=args.fault_plan, seed=args.seed)


if __name__ == "__main__":
    raise SystemExit(main())
