import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs (no allocation), prove the
sharding config is coherent, and extract the roofline raw terms.

XLA's cost_analysis counts a ``while`` (lax.scan) body ONCE regardless of
trip count, so per-(arch,shape) FLOPs/bytes/collective-bytes are measured by
lowering two reduced-layer-count variants (L1, L2 — chosen to preserve the
arch's structural pattern) and extrapolating linearly to the full depth:
    m(L) = m(L1) + (L - L1) * (m(L2) - m(L1)) / (L2 - L1).
The FULL config is still compiled (that is the fits-and-lowers proof and the
memory_analysis source); only the cost terms use the interpolation.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.core.scheduler import SyncConfig
from repro.data.pipeline import make_batch_specs
from repro.dist import sharding as SH
from repro.dist.train import (make_decode_step, make_elastic_train_step,
                              make_prefill_step, make_train_step)
from repro.launch.mesh import make_production_mesh
from repro.models import actx
from repro.models import transformer as TF
from repro.models.params import abstract_params, param_specs
from repro.optim import momentum, sgd

# ---------------------------------------------------------------------------
# input_specs (deliverable: ShapeDtypeStruct stand-ins for every model input)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape, flags: TF.RunFlags):
    """ShapeDtypeStructs for one workload: batch dict (+ cache for decode)."""
    batch = make_batch_specs(cfg, shape)
    if shape.kind != "decode":
        return {"batch": batch}
    cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, shape.global_batch, shape.seq_len, flags))
    return {"batch": batch, "cache": cache}


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the post-SPMD
    (compiled) HLO, weighted by ring traffic factor (all-reduce ~2x; others
    ~1x their payload). XLA groups several tensors into one tuple-shaped
    collective — all tuple element shapes are summed. ``-done`` ops and
    operand mentions (inside fusions / get-tuple-element) are skipped."""
    out: dict = {}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        lhs, _, rhs = line.partition(" = ")
        for kind in _COLL_OPS:
            tok = rhs.find(kind)
            if tok < 0:
                continue
            after = rhs[tok + len(kind):]
            # accept "(", "-start(", ".12 = ..." forms; reject operand refs
            if not (after.startswith("(") or after.startswith("-start(")):
                continue
            b = _shape_bytes(rhs[:tok])
            factor = 2.0 if kind == "all-reduce" else 1.0
            out[kind] = out.get(kind, 0.0) + factor * b
            break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

def pick_optimizer(cfg: ArchConfig):
    """Paper-faithful default: SGD + momentum 0.9. grok-1's 314B of fp32
    master+momentum state does not fit 256 chips; it uses stateless SGD
    (documented in DESIGN.md / EXPERIMENTS.md)."""
    if cfg.name.startswith("grok"):
        return sgd(1e-2), "sgd"
    return momentum(1e-2, 0.9), "momentum"


def use_fsdp(cfg: ArchConfig) -> bool:
    """Shard params over the data axis too when replicated master+momentum
    would exceed ~6GB/device on the single-pod mesh."""
    per_dev = cfg.param_count() * 4 * 2 / 16  # fp32 x (param+momentum) /model
    return per_dev > 6e9


def build_flags(cfg: ArchConfig, shape: InputShape, mesh) -> TF.RunFlags:
    return TF.RunFlags(remat=True)


SEQUENCE_PARALLEL = False  # baseline OFF; flipped by --sp (a §Perf lever)


def act_rules_for(cfg: ArchConfig, mesh, shape: InputShape, *,
                  batch_axes: bool = True) -> dict:
    return SH.make_act_rules(
        cfg, mesh, batch_size=shape.global_batch,
        seq_len=shape.seq_len if shape.kind != "decode" else 1,
        sequence_parallel=SEQUENCE_PARALLEL and shape.kind != "decode",
        batch_axes=batch_axes)


GRAD_ACCUM = 1  # microbatching lever (--accum); baseline 1
WIRE_DTYPE = "f32"  # gradient-sync wire dtype (--wire-dtype); baseline f32


def lower_train(cfg: ArchConfig, mesh, shape: InputShape,
                sync: str = "exact", static_phase: int = 0):
    flags = build_flags(cfg, shape, mesh)
    sizes = SH.axis_sizes(mesh)
    fsdp = ("data",) if (use_fsdp(cfg) and sync == "exact") else ()
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, sizes, fsdp_axes=fsdp)
    ab_params = abstract_params(defs)
    opt, _ = pick_optimizer(cfg)
    ab_opt = jax.eval_shape(opt.init, ab_params)
    ospecs = SH.opt_state_specs(ab_opt, pspecs)
    batch = make_batch_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, mesh, batch)

    if sync == "exact":
        step = make_train_step(cfg, opt, flags, grad_accum=GRAD_ACCUM)
        jitted = jax.jit(
            step,
            in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                          SH.named(mesh, bspecs)),
            out_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                           None),
            donate_argnums=(0, 1))
        with actx.rules(act_rules_for(cfg, mesh, shape)):
            return jitted.lower(ab_params, ab_opt, batch)

    scfg = SyncConfig(
        strategy=sync, axis_names=SH.data_axes(mesh),
        wire_dtype=WIRE_DTYPE,
        gate="static" if sync == "elastic" else "norm")
    from repro.dist.train import init_dist_sync_state
    ab_sync = jax.eval_shape(lambda: init_dist_sync_state(scfg, mesh,
                                                          ab_params))
    # per-worker entries (EF error / elastic residual) shard their leading
    # worker dim over the data axes and keep the params' model sharding
    sspecs = SH.sync_state_specs(ab_sync, pspecs, mesh)
    step = make_elastic_train_step(cfg, opt, mesh, scfg, pspecs, flags,
                                   static_phase=static_phase)
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                      SH.named(mesh, sspecs), SH.named(mesh, bspecs)),
        out_shardings=(SH.named(mesh, pspecs), SH.named(mesh, ospecs),
                       SH.named(mesh, sspecs), None),
        donate_argnums=(0, 1, 2))
    with actx.rules(act_rules_for(cfg, mesh, shape, batch_axes=False)):
        return jitted.lower(ab_params, ab_opt, ab_sync, batch)


def lower_prefill(cfg: ArchConfig, mesh, shape: InputShape):
    flags = build_flags(cfg, shape, mesh)
    sizes = SH.axis_sizes(mesh)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, sizes)
    ab_params = abstract_params(defs)
    batch = make_batch_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, mesh, batch)
    ab_cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, shape.global_batch, shape.seq_len, flags))
    cspecs = SH.cache_specs(cfg, mesh, ab_cache)
    step = make_prefill_step(cfg, shape.seq_len, flags)
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, bspecs)),
        out_shardings=(NamedSharding(mesh, SH.batch_spec(
            mesh, shape.global_batch)), SH.named(mesh, cspecs)))
    with actx.rules(act_rules_for(cfg, mesh, shape)):
        return jitted.lower(ab_params, batch)


def lower_decode(cfg: ArchConfig, mesh, shape: InputShape):
    flags = build_flags(cfg, shape, mesh)
    sizes = SH.axis_sizes(mesh)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, sizes)
    ab_params = abstract_params(defs)
    ab_cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, shape.global_batch, shape.seq_len, flags))
    cspecs = SH.cache_specs(cfg, mesh, ab_cache)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tspec = NamedSharding(
        mesh, P(*(tuple(SH.batch_spec(mesh, shape.global_batch)) + (None,))))
    step = make_decode_step(cfg, flags)
    jitted = jax.jit(
        step,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, cspecs), tspec),
        out_shardings=(NamedSharding(mesh, SH.batch_spec(
            mesh, shape.global_batch)), SH.named(mesh, cspecs)),
        donate_argnums=(1,))
    with actx.rules(act_rules_for(cfg, mesh, shape)):
        return jitted.lower(ab_params, ab_cache, tokens)


def lower_for(cfg: ArchConfig, mesh, shape: InputShape, sync="exact",
              static_phase: int = 0):
    if shape.kind == "train":
        return lower_train(cfg, mesh, shape, sync, static_phase)
    if shape.kind == "prefill":
        return lower_prefill(cfg, mesh, shape)
    return lower_decode(cfg, mesh, shape)


# ---------------------------------------------------------------------------
# layer-count interpolation for scan-aware costs
# ---------------------------------------------------------------------------

def reduced_depths(cfg: ArchConfig) -> tuple[int, int]:
    """(0, pattern_period): the zero-layer lowering isolates the base cost
    (embedding/logits/loss/optimizer) exactly, so the expensive unrolled
    point only needs ONE structural period of depth."""
    if cfg.shared_attn_every:
        return 0, cfg.shared_attn_every
    if cfg.global_every:
        return 0, cfg.global_every
    return 0, 1


def _cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (older
    releases return a one-element list of dicts, newer a plain dict)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _costs_of(lowered) -> dict:
    compiled = lowered.compile()
    ca = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
    }


MAX_UNROLL_SEQ = 4096  # longest sequence we fully unroll for cost lowering


def _costs_at(cfg, mesh, shape, sync, static_phase, seq_len):
    s = shape
    if seq_len != shape.seq_len:
        s = dataclasses.replace(shape, seq_len=seq_len)
    return _costs_of(lower_for(cfg, mesh, s, sync, static_phase))


def _fit_seq(costs_by_seq, target):
    """Polynomial fit m(S) through the measured points (exact for our
    per-step cost structures: SSM/SWA terms linear in S, full-attention
    quadratic)."""
    xs = sorted(costs_by_seq)
    ys = [costs_by_seq[x] for x in xs]
    coef = np.polyfit(np.asarray(xs, np.float64),
                      np.asarray(ys, np.float64), deg=len(xs) - 1)
    return float(max(0.0, np.polyval(coef, target)))


def scan_aware_costs(cfg: ArchConfig, mesh, shape: InputShape,
                     sync="exact", static_phase: int = 0) -> dict:
    """FLOPs/bytes/collectives with every scan unrolled, extrapolated
    (a) linearly in layer count from two reduced depths and (b), when the
    sequence is too long to unroll (prefill_32k), quadratically in S from
    three reduced sequence lengths."""
    from repro.models.scan_utils import set_cost_unroll
    l1, l2 = reduced_depths(cfg)
    seqs = ([shape.seq_len] if shape.kind == "decode"
            or shape.seq_len <= MAX_UNROLL_SEQ
            else [1024, 2048, MAX_UNROLL_SEQ])

    set_cost_unroll(True)  # unroll every model scan so counts are exact
    try:
        grid = {}
        for li in (l1, l2):
            ci = dataclasses.replace(cfg, n_layers=li)
            for s in seqs:
                grid[(li, s)] = _costs_at(ci, mesh, shape, sync,
                                          static_phase, s)
    finally:
        set_cost_unroll(False)

    def metric(c, key, kind=None):
        return c["collectives"].get(kind, 0.0) if key == "coll" else c[key]

    def extrap(key, kind=None):
        # collectives (Megatron activation all-reduces) are LINEAR in S;
        # fitting them quadratically amplifies XLA partitioning-strategy
        # jumps between sizes. flops/bytes keep the quadratic model (full
        # attention really is O(S^2)).
        pts = seqs if key != "coll" else seqs[-2:]
        per_depth = {}
        for li in (l1, l2):
            per_depth[li] = _fit_seq(
                {s: metric(grid[(li, s)], key, kind) for s in pts},
                shape.seq_len)
        per = (per_depth[l2] - per_depth[l1]) / (l2 - l1)
        return max(0.0, per_depth[l1] + (cfg.n_layers - l1) * per)

    coll_kinds = set()
    for c in grid.values():
        coll_kinds |= set(c["collectives"])
    return {
        "flops": extrap("flops"),
        "bytes": extrap("bytes"),
        "collectives": {k: extrap("coll", k) for k in coll_kinds},
        "interpolation": {
            "l1": l1, "l2": l2, "seqs": seqs,
            "grid": {f"L{li}_S{s}": c for (li, s), c in grid.items()},
        },
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def should_skip(cfg: ArchConfig, shape: InputShape):
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is full-attention (see DESIGN.md)")
    return None


def run_one(arch: str, shape_name: str, multi_pod: bool, sync: str = "exact",
            with_costs: bool = True, static_phase: int = 0) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "sync": sync,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "sequence_parallel": SEQUENCE_PARALLEL,
        "status": "ok",
    }
    skip = should_skip(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_for(cfg, mesh, shape, sync, static_phase)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_per_device_gb": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    }
    print(f"  memory_analysis: {ma}")
    ca = _cost_analysis(compiled)
    rec["cost_analysis_raw"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    print(f"  cost_analysis(raw, scan-body-once): {rec['cost_analysis_raw']}")

    if with_costs:
        rec["costs"] = scan_aware_costs(cfg, mesh, shape, sync, static_phase)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sync", default="exact")
    ap.add_argument("--static-phase", type=int, default=0)
    ap.add_argument("--no-costs", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="enable sequence parallelism (a perf lever; "
                         "baseline keeps it off)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (perf lever)")
    ap.add_argument("--wire-dtype", default="f32", choices=["f32", "bf16"],
                    help="gradient-sync wire dtype (perf lever)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    global SEQUENCE_PARALLEL, GRAD_ACCUM, WIRE_DTYPE
    if args.sp:
        SEQUENCE_PARALLEL = True
    GRAD_ACCUM = args.accum
    WIRE_DTYPE = args.wire_dtype

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = (f"{arch}__{shape}__"
                       f"{'multi' if mp else 'single'}__{args.sync}"
                       + ("__sp" if args.sp else "")
                       + (f"__accum{args.accum}" if args.accum > 1 else "")
                       + ("__bf16wire" if args.wire_dtype == "bf16" else ""))
                out_path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(out_path):
                    rec = json.load(open(out_path))
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"=== {tag} === (cached)", flush=True)
                        continue
                print(f"=== {tag} ===", flush=True)
                try:
                    rec = run_one(arch, shape, mp, args.sync,
                                  with_costs=not args.no_costs and not mp,
                                  static_phase=args.static_phase)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "sync": args.sync, "status": "failed",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"  -> {rec['status']}", flush=True)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
