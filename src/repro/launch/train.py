"""End-to-end training driver (CPU-scale; same code path as the pod).

Runs a reduced (or full, on real hardware) architecture with a real data
pipeline, optimizer, checkpointing and any gradient-sync strategy:

  python -m repro.launch.train --arch qwen3-1.7b-smoke --steps 200 \
      --sync elastic --devices 8 --ckpt-dir /tmp/ckpt

``--devices N`` forces N host devices (set before jax initializes) so the
data-parallel sync strategies are exercised with real cross-shard traffic.
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sync", default="exact",
                    choices=["exact", "topk_ef", "onebit_ef", "elastic",
                             "async"])
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--budget-b", type=float, default=0.0)
    ap.add_argument("--topk-ratio", type=float, default=1 / 16)
    # --sync async: the bounded-staleness engine (repro.dist.async_engine)
    ap.add_argument("--tau-max", type=int, default=4)
    ap.add_argument("--async-schedule", default="uniform",
                    choices=["constant", "uniform", "roundrobin",
                             "straggler", "crash", "rejoin"])
    ap.add_argument("--compressor", default="none",
                    choices=["none", "topk", "onebit"])
    ap.add_argument("--ef", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="error feedback for --compressor (async path)")
    ap.add_argument("--crash-subst", action="store_true",
                    help="async: renormalize dead-worker mass so survivors "
                         "keep the full step size (paper crash_subst)")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="async + --compressor: the fused overlapped "
                         "compress-then-reduce delivery (compact wire); "
                         "--no-overlap keeps the densified sync-wire "
                         "delivery (also the fallback when tensor "
                         "parallelism is on)")
    # fault injection (repro.faults): a plan path or inline JSON; the
    # supervisor forwards --fault-attempt so kill events fire exactly once
    ap.add_argument("--fault-plan", default="")
    ap.add_argument("--fault-attempt", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import save_checkpoint, latest_step, load_checkpoint
    from repro.configs import get_config
    from repro.core.scheduler import SyncConfig
    from repro.data.pipeline import SyntheticLMDataset
    from repro.dist import sharding as SH
    from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                         make_async_train_step)
    from repro.dist.train import (init_dist_sync_state,
                                  make_elastic_train_step, make_train_step)
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as TF
    from repro.models.params import init_params, param_specs
    from repro.optim import momentum

    cfg = get_config(args.arch)
    injector = None
    if args.fault_plan:
        from repro.faults import FaultPlan, TrainFaultInjector
        injector = TrainFaultInjector(FaultPlan.load(args.fault_plan),
                                      attempt=args.fault_attempt)
    guard = injector is not None and injector.has_poison
    mesh = make_host_mesh(model=args.model_shards)
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params = init_params(defs, jax.random.PRNGKey(args.seed))
    opt = momentum(args.lr, 0.9)
    opt_state = opt.init(params)

    data = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch,
                              seed=args.seed)

    # the poison guard only arms the paths that implement it; a poison plan
    # with --sync elastic would corrupt params silently, so refuse it
    if guard and args.sync not in ("exact", "async"):
        raise SystemExit("--fault-plan with grad_poison events needs "
                         "--sync exact or async (the skip-step guard)")

    if args.sync == "exact":
        sync_state = {"step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_train_step(cfg, opt, flags, skip_nonfinite=guard),
                       donate_argnums=(0, 1))

        def run(params, opt_state, sync_state, batch):
            params, opt_state, metrics = step(params, opt_state, batch)
            return params, opt_state, sync_state, metrics
    elif args.sync == "async":
        # horizon is decoupled from --steps (up to 1024) so resuming with a
        # larger --steps reuses the checkpointed tau table unchanged and
        # never wraps it.  The crash/rejoin schedules are the exception:
        # their outage points are horizon fractions, so their tables must be
        # run-length-aligned for workers to actually die mid-run — extending
        # such a run needs the original --steps (the resume shape guard
        # enforces this).
        horizon = max(args.steps, 1) \
            if args.async_schedule in ("crash", "rejoin") \
            else max(args.steps, 1024)
        overlap = args.overlap
        if overlap and args.compressor != "none" and args.model_shards > 1:
            # jax-0.4.x SPMD partitioner: no all_gather under partial-auto
            # shard_map on tensor-parallel meshes (ROADMAP toolchain bump)
            print("overlap: disabled (compact-wire all_gather needs "
                  "--model-shards 1 on this toolchain); using the "
                  "densified delivery", flush=True)
            overlap = False
        acfg = AsyncConfig(
            tau_max=args.tau_max, schedule=args.async_schedule,
            axis_names=("data",), compressor=args.compressor,
            error_feedback=args.ef, topk_ratio=args.topk_ratio,
            horizon=horizon, seed=args.seed,
            crash_subst=args.crash_subst, skip_nonfinite=guard,
            overlap=overlap)
        sync_state = init_async_state(acfg, mesh, params,
                                      pspecs if acfg.fused else None)
        if injector is not None and injector.plan.has_tau_events:
            # scheduled crash/rejoin/delay/drop faults rewrite the pre-drawn
            # tau table — the engine then runs them with no new code, and a
            # resume restores the SAME rewritten table from the checkpoint
            sync_state["taus"] = jnp.asarray(injector.plan.apply_to_taus(
                np.asarray(sync_state["taus"]), args.tau_max))
        astep = make_async_train_step(cfg, opt, mesh, acfg, pspecs, flags)
        jstep = jax.jit(astep, donate_argnums=(0, 1, 2))

        def run(params, opt_state, sync_state, batch):
            return jstep(params, opt_state, sync_state, batch)
    else:
        scfg = SyncConfig(
            strategy=args.sync, axis_names=("data",),
            topk_ratio=args.topk_ratio, beta=args.beta,
            budget_b=args.budget_b,
            gate="norm")
        sync_state = init_dist_sync_state(scfg, mesh, params)
        estep = make_elastic_train_step(cfg, opt, mesh, scfg, pspecs, flags)
        jstep = jax.jit(estep, donate_argnums=(0, 1, 2))

        def run(params, opt_state, sync_state, batch):
            return jstep(params, opt_state, sync_state, batch)

    step_idx = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            restored = load_checkpoint(args.ckpt_dir, last)
            if len(restored) == 3:
                # delay rings / EF residuals / tau-table position resume
                # with the params — a mid-flight stale gradient survives
                # the restart (tests/test_ckpt_roundtrip.py)
                params, opt_state, ckpt_state = restored
                if jax.tree.map(np.shape, sync_state) != \
                        jax.tree.map(np.shape, ckpt_state):
                    raise ValueError(
                        "checkpointed sync/async state does not match the "
                        "current --sync configuration (different strategy, "
                        "--tau-max, --compressor, --ef, --overlap, or a "
                        "--steps change that resized the tau table?) — "
                        "delay rings and tau schedules cannot be "
                        "reinterpreted; resume with the original flags or "
                        "use a fresh --ckpt-dir")
                sync_state = ckpt_state
            else:  # legacy (params, opt_state) checkpoints
                params, opt_state = restored
            step_idx = last
            print(f"resumed from step {last}")

    losses = []
    skipped = 0
    for t in range(step_idx, args.steps):
        batch = data.batch(t)
        if guard:
            # the loss_scale channel: all-ones normally, NaN/Inf on
            # grad_poison steps; (B,)-shaped so the batch stays uniformly
            # shardable.  Present on EVERY step once armed — one program,
            # and a benign scale of 1.0 is bitwise-neutral
            batch = dict(batch, loss_scale=np.full(
                (args.batch,), injector.loss_scale(t), np.float32))
        params, opt_state, sync_state, metrics = run(
            params, opt_state, sync_state, batch)
        losses.append(float(metrics["loss"]))
        skipped += int(float(metrics.get("nonfinite", 0.0)) > 0)
        if t % args.log_every == 0:
            gap = float(metrics.get("gap2_over_alpha2",
                                    metrics.get("stale_gap2", 0.0)))
            tau = ""
            if "mean_tau" in metrics:
                tau = f"  tau {float(metrics['mean_tau']):.2f}"
            print(f"step {t:5d}  loss {losses[-1]:.4f}  gap2/a2 {gap:.4g}"
                  f"{tau}", flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (t + 1) % args.ckpt_every == 0:
            try:
                if injector is not None:
                    injector.check_ckpt_io(t + 1)
                save_checkpoint(args.ckpt_dir, t + 1,
                                (params, opt_state, sync_state))
            except OSError as e:
                # checkpointing is best-effort: warn and keep training —
                # the next save (or the torn-ckpt skip in latest_step)
                # covers recovery
                print(f"ckpt save failed at step {t + 1}: {e}", flush=True)
        if injector is not None:
            injector.maybe_kill(t)
    if injector is not None:
        print(f"faults: poisoned={injector.poisoned_steps} "
              f"skipped={skipped} ckpt_errors={injector.ckpt_errors}",
              flush=True)
        finite = [l for l in losses[-10:] if np.isfinite(l)]
        print(f"final loss {np.mean(finite if finite else losses[-10:]):.4f}")
    else:
        print(f"final loss {np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
