"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is an
outer data-parallel axis whose gradient traffic crosses the (slow) inter-pod
links — exactly where the paper's communication-reduction matters most.

Mesh creation routes through `repro.jax_compat` so the ``AxisType`` /
``axis_types=`` API drift across jax versions is absorbed in one place.
"""
from __future__ import annotations

import jax

from repro.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh for CPU-scale smoke runs (1 device)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
