"""Mamba2 (SSD) block — chunked parallel scan formulation.

State-space recurrence per head h (scalar decay a_t, state S in R^{hd x N}):

    S_t = a_t * S_{t-1} + dt_t * x_t (x) B_t          a_t = exp(A_h * dt_t)
    y_t = S_t @ C_t + D_h * x_t

The chunked form computes, for chunk length C:
  * intra-chunk: y_t += sum_{j<=t} exp(cum_t - cum_j) * (C_t . B_j) dt_j x_j
    via a (C, C) decay-masked attention-like matrix per head (MXU matmuls),
  * inter-chunk: carried state S contributes y_t += exp(cum_t) * S_prev @ C_t,
    and S is updated once per chunk — `lax.scan` over chunks keeps the HLO
    compact for the 81-layer zamba2 stack.

Sharding (§Perf iteration zamba2/1): projections are SPLIT (z, x, dt
column-parallel over `model`; B/C replicated — they are tiny and shared
across heads) instead of one fused in_proj whose output dim (2*di+2n+h)
doesn't divide the model axis. The fused form forced XLA to replicate every
mamba activation across all 16 model shards (~16x HBM traffic + an
all-reduce per projection); the split form keeps the inner di dim and the
head dim sharded end-to-end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.actx import constrain
from repro.models.params import ParamDef

CHUNK = 128


def mamba2_defs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    return {
        "z_proj": ParamDef((d, di), ("embed", "dinner")),
        "x_proj": ParamDef((d, di), ("embed", "dinner")),
        "b_proj": ParamDef((d, n), (None, None)),       # tiny: replicate
        "c_proj": ParamDef((d, n), (None, None)),
        "dt_proj": ParamDef((d, h), (None, "heads")),
        "conv_x_w": ParamDef((cfg.conv_width, di), (None, "dinner"),
                             scale=cfg.conv_width ** -0.5),
        "conv_x_b": ParamDef((di,), ("dinner",), init="zeros"),
        "conv_bc_w": ParamDef((cfg.conv_width, 2 * n), (None, None),
                              scale=cfg.conv_width ** -0.5),
        "conv_bc_b": ParamDef((2 * n,), (None,), init="zeros"),
        "a_log": ParamDef((h,), ("heads",), init="constant", constant=0.0),
        "dt_bias": ParamDef((h,), ("heads",), init="zeros"),
        "d_skip": ParamDef((h,), ("heads",), init="ones"),
        "gate_norm": ParamDef((di,), ("dinner",), init="ones"),
        "out_proj": ParamDef((di, d), ("dinner", "embed")),
    }


def _causal_conv(x, w, b, carry=None):
    """Depthwise causal conv. x: (B, T, Cd); w: (W, Cd). carry: (B, W-1, Cd)
    of trailing inputs from the previous segment (for decode)."""
    width = w.shape[0]
    if carry is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_carry = xp[:, -(width - 1):]
    return jax.nn.silu(out + b), new_carry


def ssd_chunked(xh, a, bmat, cmat, state0=None):
    """Chunked SSD scan.

    xh:   (B, T, H, hd)   inputs (already dt-scaled)
    a:    (B, T, H)       per-step log-decay (<= 0)
    bmat: (B, T, N)       input projection (shared across heads)
    cmat: (B, T, N)       output projection
    state0: (B, H, hd, N) or None
    Returns (y (B,T,H,hd), final_state).
    """
    b, t, h, hd = xh.shape
    n = bmat.shape[-1]
    c = min(CHUNK, t)
    assert t % c == 0, (t, c)
    nc = t // c
    xh = xh.reshape(b, nc, c, h, hd)
    a = a.reshape(b, nc, c, h)
    bmat = bmat.reshape(b, nc, c, n)
    cmat = cmat.reshape(b, nc, c, n)
    if state0 is None:
        state0 = jnp.zeros((b, h, hd, n), jnp.float32)

    def step(state, inp):
        xc, ac, bc, cc = inp  # (b,c,h,hd), (b,c,h), (b,c,n), (b,c,n)
        cum = jnp.cumsum(ac, axis=1)                      # (b,c,h) inclusive
        # intra-chunk: decay matrix L[t,j] = exp(cum_t - cum_j), j <= t
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]   # (b,c,c,h)
        mask = jnp.tril(jnp.ones((c, c), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)
        cb = jnp.einsum("btn,bjn->btj", cc, bc,
                        preferred_element_type=jnp.float32)  # (b,c,c)
        amat = cb[:, :, :, None] * lmat                   # (b,c,c,h)
        y = jnp.einsum("btjh,bjhd->bthd", amat.astype(xc.dtype), xc,
                       preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        decay_t = jnp.exp(cum)                            # (b,c,h)
        y = y + jnp.einsum("bth,bhdn,btn->bthd",
                           decay_t, state, cc.astype(jnp.float32))
        # state update
        decay_rest = jnp.exp(cum[:, -1:, :] - cum)        # (b,c,h)
        kd = bc[:, :, None, :] * decay_rest[..., None]    # (b,c,h,n)
        new_state = jnp.exp(cum[:, -1])[:, :, None, None] * state + \
            jnp.einsum("bchn,bchd->bhdn", kd, xc.astype(jnp.float32))
        return new_state, y.astype(xc.dtype)

    xs = (xh.swapaxes(0, 1), a.swapaxes(0, 1),
          bmat.swapaxes(0, 1), cmat.swapaxes(0, 1))
    from repro.models.scan_utils import scan as _scan
    final, ys = _scan(step, state0, xs)
    y = ys.swapaxes(0, 1).reshape(b, t, h, hd)
    return y, final


def mamba2_block(params, cfg, x, *, state=None):
    """x: (B, T, d). state: None (train/prefill) or dict(conv_x, conv_bc,
    ssm) for decode continuation. Returns (out (B,T,d), new_state)."""
    b, t, d = x.shape
    dt_ = x.dtype
    di = cfg.ssm_expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    hd = di // h

    z = constrain(x @ params["z_proj"].astype(dt_), "ssm_inner")
    xin = constrain(x @ params["x_proj"].astype(dt_), "ssm_inner")
    bc = jnp.concatenate(
        [x @ params["b_proj"].astype(dt_), x @ params["c_proj"].astype(dt_)],
        axis=-1)
    dt_raw = x @ params["dt_proj"].astype(dt_)            # (B,T,H)

    cx = None if state is None else state["conv_x"]
    cbc = None if state is None else state["conv_bc"]
    xin, new_cx = _causal_conv(
        xin, params["conv_x_w"].astype(dt_), params["conv_x_b"].astype(dt_),
        cx)
    xin = constrain(xin, "ssm_inner")
    bc, new_cbc = _causal_conv(
        bc, params["conv_bc_w"].astype(dt_), params["conv_bc_b"].astype(dt_),
        cbc)
    bmat, cmat = jnp.split(bc, 2, axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # (B,T,H)
    a_neg = -jnp.exp(params["a_log"])                    # (H,) < 0
    log_decay = dt * a_neg                               # (B,T,H) <= 0

    xh = constrain(xin.reshape(b, t, h, hd) * dt[..., None].astype(dt_),
                   "ssm_heads")
    ssm0 = None if state is None else state["ssm"]
    y, new_ssm = ssd_chunked(xh, log_decay, bmat, cmat, ssm0)
    y = constrain(y, "ssm_heads") \
        + params["d_skip"].astype(dt_)[None, None, :, None] \
        * xin.reshape(b, t, h, hd)
    y = y.reshape(b, t, di)

    from repro.models.layers import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    new_state = {"conv_x": new_cx, "conv_bc": new_cbc, "ssm": new_ssm}
    return out, new_state


def mamba2_init_state(cfg, batch: int):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    return {
        "conv_x": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
        "conv_bc": jnp.zeros((batch, cfg.conv_width - 1, 2 * n), jnp.float32),
        "ssm": jnp.zeros((batch, h, di // h, n), jnp.float32),
    }
