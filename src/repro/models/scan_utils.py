"""Scan wrappers with a cost-lowering unroll switch.

XLA's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count, which would silently under-report FLOPs/bytes/collectives for every
scanned structure (layer stacks, attention query chunks, SSM time chunks).
For the roofline cost lowerings the dry-run flips ``set_cost_unroll(True)``
so every model scan fully unrolls (reduced-depth configs keep this tractable)
and the counts are exact; production/compile-proof lowerings keep compact
``while`` loops.
"""
from __future__ import annotations

import jax

_COST_UNROLL = False


def set_cost_unroll(value: bool) -> None:
    global _COST_UNROLL
    _COST_UNROLL = bool(value)


def cost_unroll_enabled() -> bool:
    return _COST_UNROLL


def scan(body, carry, xs, **kw):
    if _COST_UNROLL:
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, carry, xs, **kw)


def lmap(fn, xs):
    if _COST_UNROLL:
        import jax.numpy as jnp
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
        return jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return jax.lax.map(fn, xs)
