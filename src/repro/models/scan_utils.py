"""Scan wrappers with unroll switches.

XLA's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count, which would silently under-report FLOPs/bytes/collectives for every
scanned structure (layer stacks, attention query chunks, SSM time chunks).
For the roofline cost lowerings the dry-run flips ``set_cost_unroll(True)``
so every model scan fully unrolls (reduced-depth configs keep this tractable)
and the counts are exact; production/compile-proof lowerings keep compact
``while`` loops.

:func:`unrolled` is a second, scoped unroll switch for a different reason:
on the jax 0.4.x series, a ``while`` loop (``lax.scan``/``lax.map``) inside
a *partial-auto* ``shard_map`` (manual data axes + GSPMD-managed ``model``
axis) trips a fatal check in XLA's SPMD partitioner
(``hlo_sharding_util.cc: sharding.IsManualSubgroup()``). The elastic train
step wraps its body in ``unrolled(...)`` whenever auto axes are present so
tensor-parallel lowerings compile; meshes without a >1 ``model`` axis (all
CPU smoke/system tests) keep the compact scan.
"""
from __future__ import annotations

import contextlib

import jax

_COST_UNROLL = False
_FORCE_UNROLL = 0  # nesting depth of `unrolled(True)` contexts


def set_cost_unroll(value: bool) -> None:
    global _COST_UNROLL
    _COST_UNROLL = bool(value)


def cost_unroll_enabled() -> bool:
    return _COST_UNROLL


@contextlib.contextmanager
def unrolled(enable: bool = True):
    """Scoped unroll of every model scan traced inside the context."""
    global _FORCE_UNROLL
    if enable:
        _FORCE_UNROLL += 1
    try:
        yield
    finally:
        if enable:
            _FORCE_UNROLL -= 1


def _unroll_now() -> bool:
    return _COST_UNROLL or _FORCE_UNROLL > 0


def scan(body, carry, xs, **kw):
    if _unroll_now():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, carry, xs, **kw)


def lmap(fn, xs):
    if _unroll_now():
        import jax.numpy as jnp
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
        return jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return jax.lax.map(fn, xs)
