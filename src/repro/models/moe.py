"""GShard-style capacity-based Mixture-of-Experts layer.

TPU-native formulation: routing produces dense dispatch/combine tensors and
the expert FFN is a batched einsum with the expert dim sharded over the
``model`` mesh axis (expert parallelism). When tokens are sharded over the
``data`` axis and experts over ``model``, XLA lowers the dispatch einsums to
all-to-all / collective-permute schedules — the MoE communication pattern the
roofline's collective term tracks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.actx import constrain
from repro.models.params import ParamDef

# Tokens are routed within fixed-size groups so the dispatch tensor is
# O(tokens * k * capacity_factor) rather than O(tokens * seq * ...).
GROUP_SIZE = 512


def moe_defs(cfg) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    return {
        "router": ParamDef((d, e), ("embed", None), scale=d ** -0.5),
        "w_gate": ParamDef((e, d, ff), ("experts", "embed", "ff")),
        "w_up": ParamDef((e, d, ff), ("experts", "embed", "ff")),
        "w_down": ParamDef((e, ff, d), ("experts", "ff", "embed")),
    }


def capacity(group: int, k: int, n_experts: int, factor: float) -> int:
    cap = int(group * k * factor / n_experts)
    return max(4, -(-cap // 4) * 4)  # round up to a multiple of 4


def route(router_logits: jax.Array, k: int, cap: int):
    """Top-k routing with per-expert capacity.

    router_logits: (G, T, E). Returns (dispatch (G,T,E,C) bool-ish,
    combine (G,T,E,C) f32, aux_loss scalar).
    """
    g, t, e = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    # sort-based top-k (same tie-breaking as lax.top_k: lowest index wins);
    # the TopK custom-call trips the jax-0.4.x SPMD partitioner inside the
    # elastic trainer's partial-auto shard_map, sort partitions fine
    topk_idx = jnp.argsort(-probs, axis=-1)[..., :k]          # (G, T, k)
    topk_probs = jnp.take_along_axis(probs, topk_idx, axis=-1)
    topk_probs = topk_probs / jnp.sum(topk_probs, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard form)
    me = jnp.mean(probs, axis=1)                               # (G, E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx, e), axis=2), axis=1)  # (G, E)
    aux = jnp.mean(me * ce) * e * e

    # position of each (token, choice) within its expert's capacity buffer
    sel = jax.nn.one_hot(topk_idx, e, dtype=jnp.int32)         # (G, T, k, E)
    flat = sel.reshape(g, t * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat            # (G, T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(g, t, k)
    fits = pos < cap

    w = topk_probs * fits.astype(topk_probs.dtype)             # (G, T, k)
    onehot_cap = jax.nn.one_hot(jnp.where(fits, pos, cap), cap + 1,
                                dtype=jnp.float32)[..., :cap]  # (G,T,k,C)
    # (G, T, E, C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", w,
                         sel.astype(jnp.float32), onehot_cap)
    dispatch = jnp.einsum("gtke,gtkc->gtec", sel.astype(jnp.float32),
                          onehot_cap * fits[..., None].astype(jnp.float32))
    return dispatch, combine, aux


def moe_block(params, cfg, x: jax.Array):
    """x: (B, S, d) -> (B, S, d), plus aux loss."""
    b, s, d = x.shape
    dt = x.dtype
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    gsz = min(GROUP_SIZE, n)
    assert n % gsz == 0, (n, gsz)
    groups = tokens.reshape(n // gsz, gsz, d)

    logits = jnp.einsum("gtd,de->gte", groups, params["router"].astype(dt))
    cap = capacity(gsz, k, e, cfg.capacity_factor)
    dispatch, combine, aux = route(logits, k, cap)

    xe = constrain(jnp.einsum("gtec,gtd->egcd", dispatch.astype(dt),
                              groups), "moe_expert")
    gate = jax.nn.silu(constrain(jnp.einsum(
        "egcd,edf->egcf", xe, params["w_gate"].astype(dt)), "moe_hidden"))
    up = constrain(jnp.einsum("egcd,edf->egcf", xe,
                              params["w_up"].astype(dt)), "moe_hidden")
    out_e = constrain(jnp.einsum("egcf,efd->egcd", gate * up,
                                 params["w_down"].astype(dt)), "moe_expert")
    out = jnp.einsum("egcd,gtec->gtd", out_e, combine.astype(dt))
    return out.reshape(b, s, d), aux
