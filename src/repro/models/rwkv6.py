"""RWKV-6 (Finch) block — attention-free, data-dependent per-channel decay.

Time-mixing recurrence per head (k/v dim N):

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t        w_t = exp(-exp(w_raw(x_t)))
    o_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)

Chunked evaluation (chunk C) mirrors Mamba2's SSD but with *vector* decays:
the intra-chunk kernel is L[t,j,i] = exp(lw_t[i] - lw_j[i]) for j < t, which
is computed as a (C, C, N) tensor per (batch, head) — numerically safe since
lw is a running sum of negative log-decays (t >= j => exponent <= 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.models.layers import rmsnorm

CHUNK = 64
MIX_KEYS = ("r", "k", "v", "w", "g")


def rwkv6_defs(cfg) -> dict:
    d = cfg.d_model
    h, n = cfg.ssm_heads, cfg.ssm_state
    assert h * n == d, (h, n, d)
    defs = {
        "ln1": ParamDef((d,), ("embed",), init="ones"),
        "ln2": ParamDef((d,), ("embed",), init="ones"),
        "mix": ParamDef((len(MIX_KEYS), d), (None, "embed"), init="zeros"),
        "w_r": ParamDef((d, d), ("embed", "dinner")),
        "w_k": ParamDef((d, d), ("embed", "dinner")),
        "w_v": ParamDef((d, d), ("embed", "dinner")),
        "w_g": ParamDef((d, d), ("embed", "dinner")),
        # data-dependent decay projection (low-rank in the release; dense here)
        "w_decay": ParamDef((d, d), ("embed", "dinner"), scale=0.01),
        "decay_bias": ParamDef((d,), ("embed",), init="constant", constant=-4.0),
        "bonus_u": ParamDef((h, n), (None, None), init="zeros"),
        "ln_x": ParamDef((d,), ("embed",), init="ones"),
        "w_o": ParamDef((d, d), ("dinner", "embed")),
        # channel-mix
        "cm_mix": ParamDef((2, d), (None, "embed"), init="zeros"),
        "cm_k": ParamDef((d, cfg.d_ff), ("embed", "ff")),
        "cm_v": ParamDef((cfg.d_ff, d), ("ff", "embed")),
        "cm_r": ParamDef((d, d), ("embed", "dinner")),
    }
    return defs


def _token_shift(x, last):
    """x: (B,T,d); last: (B,1,d) previous segment's final token (or zeros)."""
    return jnp.concatenate([last.astype(x.dtype), x[:, :-1]], axis=1)


def wkv6_chunked(r, k, v, log_w, u, state0=None):
    """r/k/v: (B,T,H,N); log_w: (B,T,H,N) (<0); u: (H,N).
    Returns (out (B,T,H,N), final_state (B,H,N,N))."""
    b, t, h, n = r.shape
    c = min(CHUNK, t)
    assert t % c == 0, (t, c)
    nc = t // c
    rs, ks, vs, ws = (a.reshape(b, nc, c, h, n).swapaxes(0, 1)
                      for a in (r, k, v, log_w))
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(state, inp):
        rc, kc, vc, wc = inp                      # (b,c,h,n)
        lw = jnp.cumsum(wc.astype(jnp.float32), axis=1)  # inclusive
        lw_excl = lw - wc.astype(jnp.float32)            # exclusive
        # intra-chunk, strictly causal (j < t): o_t sees S_{t-1}, so k_j is
        # decayed by prod_{s=j+1..t-1} w_s = exp(lw_excl_t - lw_j)
        ldiff = lw_excl[:, :, None] - lw[:, None, :, :, :]  # (b,c,c,h,n)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        lmat = jnp.where(mask[None, :, :, None, None], jnp.exp(ldiff), 0.0)
        amat = jnp.einsum("bthn,btjhn,bjhn->bthj",
                          rc.astype(jnp.float32), lmat,
                          kc.astype(jnp.float32))
        # diagonal bonus term
        diag = jnp.einsum("bthn,hn,bthn->bth", rc.astype(jnp.float32), u,
                          kc.astype(jnp.float32))
        y = jnp.einsum("bthj,bjhn->bthn", amat, vc.astype(jnp.float32))
        y = y + diag[..., None] * vc.astype(jnp.float32)
        # inter-chunk: S_prev seen by step t after decaying through 1..t-1
        y = y + jnp.einsum("bthn,bhnm->bthm",
                           rc.astype(jnp.float32) * jnp.exp(lw_excl), state)
        # state update: S_new = diag(w_total) S + sum_j decay(j+1..C) k_j (x) v_j
        decay_rest = jnp.exp(lw[:, -1:] - lw)              # (b,c,h,n)
        ktil = kc.astype(jnp.float32) * decay_rest
        new_state = jnp.exp(lw[:, -1])[..., None] * state + \
            jnp.einsum("bchn,bchm->bhnm", ktil, vc.astype(jnp.float32))
        return new_state, y.astype(rc.dtype)

    from repro.models.scan_utils import scan as _scan
    final, ys = _scan(step, state0, (rs, ks, vs, ws))
    return ys.swapaxes(0, 1).reshape(b, t, h, n), final


def rwkv6_block(params, cfg, x, *, state=None):
    """Time-mix + channel-mix, with the block's own pre-norms (the caller
    adds no norm/residual — this block returns the full residual delta).
    state: None or dict(tm_last, cm_last, wkv)."""
    b, t, d = x.shape
    dt_ = x.dtype
    h, n = cfg.ssm_heads, cfg.ssm_state

    a = rmsnorm(x, params["ln1"], cfg.norm_eps)
    tm_last = (jnp.zeros((b, 1, d), jnp.float32) if state is None
               else state["tm_last"])
    shifted = _token_shift(a, tm_last)
    mix = params["mix"].astype(dt_)

    def mixed(i):
        m = mix[i][None, None]
        return a + m * (shifted - a)

    xr, xk, xv, xw, xg = (mixed(i) for i in range(5))
    r = (xr @ params["w_r"].astype(dt_)).reshape(b, t, h, n)
    k = (xk @ params["w_k"].astype(dt_)).reshape(b, t, h, n)
    v = (xv @ params["w_v"].astype(dt_)).reshape(b, t, h, n)
    g = jax.nn.silu(xg @ params["w_g"].astype(dt_))
    w_raw = (xw @ params["w_decay"].astype(dt_)).astype(jnp.float32) \
        + params["decay_bias"]
    log_w = -jnp.exp(w_raw).reshape(b, t, h, n)        # < 0

    wkv0 = None if state is None else state["wkv"]
    o, new_wkv = wkv6_chunked(r, k, v, log_w, params["bonus_u"], wkv0)
    o = rmsnorm(o.reshape(b, t, d), params["ln_x"], cfg.norm_eps) * g
    tm_out = o @ params["w_o"].astype(dt_)

    x2 = x + tm_out
    b2 = rmsnorm(x2, params["ln2"], cfg.norm_eps)
    cm_last = (jnp.zeros((b, 1, d), jnp.float32) if state is None
               else state["cm_last"])
    shifted2 = _token_shift(b2, cm_last)
    cmix = params["cm_mix"].astype(dt_)
    xk2 = b2 + cmix[0][None, None] * (shifted2 - b2)
    xr2 = b2 + cmix[1][None, None] * (shifted2 - b2)
    kk = jnp.square(jax.nn.relu(xk2 @ params["cm_k"].astype(dt_)))
    cm_out = jax.nn.sigmoid(xr2 @ params["cm_r"].astype(dt_)) * \
        (kk @ params["cm_v"].astype(dt_))

    new_state = {
        "tm_last": a[:, -1:].astype(jnp.float32),
        "cm_last": b2[:, -1:].astype(jnp.float32),
        "wkv": new_wkv,
    }
    # returns the *residual update* (block output to be added to x by caller)
    return tm_out + cm_out, new_state


def rwkv6_init_state(cfg, batch: int):
    d = cfg.d_model
    h, n = cfg.ssm_heads, cfg.ssm_state
    return {
        "tm_last": jnp.zeros((batch, 1, d), jnp.float32),
        "cm_last": jnp.zeros((batch, 1, d), jnp.float32),
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
    }
