from repro.models.transformer import (  # noqa: F401
    RunFlags, DEFAULT_FLAGS, model_defs, forward, prefill, decode_step,
    init_cache, embed_input, lm_logits,
)
from repro.models.params import (  # noqa: F401
    ParamDef, init_params, abstract_params, param_specs, count_params,
)
