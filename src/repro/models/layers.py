"""Common transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window / decode), gated MLP.

All attention paths are query-chunked with online accumulation over KV so the
peak score tensor is (B, C, H, T_kv) for a small chunk C — the pure-JAX
"flash" pattern (the Pallas kernel in ``repro.kernels.swa_attention`` is the
TPU-optimized equivalent for the windowed decode/prefill hot path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.actx import constrain
from repro.models.params import ParamDef

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms / embeddings / rope
# ---------------------------------------------------------------------------

def rmsnorm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 2:  # (S, D/2) -> broadcast batch
        angles = angles[None]
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_defs(cfg) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        # kv projections: shard the head dim when divisible, else REPLICATE
        # (Megatron GQA convention) — row-parallel kv would force an
        # activation all-reduce per projection for a tiny weight.
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, k, hd), (None, "kv_heads", None)),
        "wv": ParamDef((d, k, hd), (None, "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones")
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return defs


def masked_attn_chunk(q, k, v, q_pos, k_pos, window, scale):
    """One query chunk attending over a KV span (clean implementation).

    q: (B, C, K, G, D); k/v: (B, T, K, D); positions absolute, k_pos == -1
    marks invalid slots. Returns (B, C, K, G, D) fp32.

    Matmuls take bf16 operands with fp32 accumulation (MXU-native); softmax
    statistics are fp32.
    """
    scores = jnp.einsum(
        "bckgd,btkd->bkgct", q, k, preferred_element_type=jnp.float32
    ) * scale
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (q.shape[0], q_pos.shape[0]))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (k.shape[0], k_pos.shape[0]))
    mask = (q_pos[:, :, None] >= k_pos[:, None, :]) & (k_pos[:, None, :] >= 0)
    if window:
        mask = mask & ((q_pos[:, :, None] - k_pos[:, None, :]) < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    row_valid = jnp.any(mask, axis=-1)                        # (B, C)
    probs = probs * row_valid[:, None, None, :, None].astype(probs.dtype)
    return jnp.einsum("bkgct,btkd->bckgd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def gqa_attention(q, k, v, *, window: int = 0, chunk: int = 256,
                  q_offset=0) -> jax.Array:
    """Causal GQA attention, query-chunked.

    q: (B, S, H, D); k/v: (B, T, K, D) with T >= S and query i at absolute
    position q_offset + i (keys at positions 0..T-1).
    For windowed attention each chunk only reads its (window + chunk) KV span
    (sub-quadratic); full attention reads all T per chunk.
    """
    b, s, h, d = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = h // nk
    scale = d ** -0.5
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nq = s // c
    qc = q.reshape(b, nq, c, nk, g, d)
    k_pos_all = jnp.arange(t)

    def one_chunk(i, q_chunk):
        q_pos = q_offset + i * c + jnp.arange(c)
        if window and t > window + c:
            span = window + c
            # align the span so it covers [q_start - window + 1, q_end]
            start = jnp.clip(q_offset + i * c + c - span, 0, t - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            k_pos = start + jnp.arange(span)
            return masked_attn_chunk(q_chunk, ks, vs, q_pos, k_pos, window, scale)
        return masked_attn_chunk(q_chunk, k, v, q_pos, k_pos_all, window, scale)

    if nq == 1:
        out = one_chunk(0, qc[:, 0])[:, None]
    else:
        from repro.models.scan_utils import lmap
        out = lmap(lambda args: one_chunk(args[0], args[1]),
                   (jnp.arange(nq), qc.swapaxes(0, 1)))
        out = out.swapaxes(0, 1)  # (B, nq, C, K, G, D)
    return out.reshape(b, s, h, d).astype(q.dtype)


def project_qkv(params, cfg, x, positions):
    """q/k/v projections + optional qk-norm + rope, shared by every
    attention consumer (training/prefill/dense decode here, the paged
    serving decode in `repro.serve.engine`) so their pre-attention math is
    identical by construction.  x: (B, S, d); returns q (B,S,H,hd) and
    k/v (B,S,K,hd)."""
    dt = x.dtype
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt)),
                  "attn_q")
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt)),
                  "attn_kv")
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt)),
                  "attn_kv")
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(params, cfg, x, positions, *, window: int,
                    kv_cache=None, cache_index=None):
    """Full attention sub-block: qkv proj, rope, attention, out proj.

    Training/prefill: kv_cache is None -> attends within x, returns (out, kv).
    Decode: kv_cache = (k_cache, v_cache) of shape (B, T, K, D), x is
    (B, 1, d) and cache_index the write position -> returns (out, new_cache).
    """
    dt = x.dtype
    hd = cfg.resolved_head_dim
    q, k, v = project_qkv(params, cfg, x, positions)

    if kv_cache is None:
        out = constrain(gqa_attention(q, k, v, window=window), "attn_q")
        new_cache = (k, v)
    else:
        k_cache, v_cache = kv_cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_index, axis=1)
        b, s, nk, _ = k_cache.shape
        g = cfg.n_heads // nk
        q5 = q.reshape(b, 1, nk, g, hd)
        k_pos = jnp.arange(s)
        # mask out slots not yet written
        k_pos = jnp.where(k_pos <= cache_index, k_pos, -1)
        out = masked_attn_chunk(
            q5, k_cache.astype(dt), v_cache.astype(dt),
            positions, k_pos, window, hd ** -0.5,
        ).reshape(b, 1, cfg.n_heads, hd).astype(dt)
        new_cache = (k_cache, v_cache)

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_defs(d: int, ff: int) -> dict:
    return {
        "w_gate": ParamDef((d, ff), ("embed", "ff")),
        "w_up": ParamDef((d, ff), ("embed", "ff")),
        "w_down": ParamDef((ff, d), ("ff", "embed")),
    }


def mlp_block(params, x):
    dt = x.dtype
    gate = jax.nn.silu(constrain(x @ params["w_gate"].astype(dt),
                                 "ffn_hidden"))
    up = constrain(x @ params["w_up"].astype(dt), "ffn_hidden")
    return (gate * up) @ params["w_down"].astype(dt)
