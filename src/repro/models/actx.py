"""Activation-sharding context.

Model code calls ``constrain(x, kind)`` at the canonical Megatron constraint
points; the launcher installs a rule set (kind -> NamedSharding) before
tracing. Without rules every call is a no-op, so CPU smoke tests and the
simulator never touch mesh state. Keeping the rules out of the model
signature lets the same forward serve pjit, shard_map (where batch axes must
be dropped from the specs) and single-host execution.

Kinds:
  residual     (B, S, d)        — between blocks (sequence parallelism)
  ffn_hidden   (B, S, ff)       — MLP hidden, model on ff
  attn_q       (B, S, H, hd)    — projected q / attention output
  attn_kv      (B, S, K, hd)    — projected k/v
  logits       (B, S, V)        — LM head output, model on V
  moe_expert   (E, G, C, d)     — dispatched expert inputs/outputs
  moe_hidden   (E, G, C, ff)    — expert FFN hidden
"""
from __future__ import annotations

import contextlib

import jax

_RULES: dict = {}


def set_rules(rules: dict) -> None:
    global _RULES
    _RULES = dict(rules or {})


def get_rules() -> dict:
    return dict(_RULES)


@contextlib.contextmanager
def rules(r: dict):
    old = get_rules()
    set_rules(r)
    try:
        yield
    finally:
        set_rules(old)


def constrain(x, kind: str):
    s = _RULES.get(kind)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
