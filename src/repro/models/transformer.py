"""Model assembly: embedding, scanned layer stacks, LM head, serve caches.

Supports four stack kinds driven by ``ArchConfig.block_type``:
  * ``attn``   — transformer blocks (attention + MLP/MoE), uniform window or
    gemma3-style grouped local:global pattern,
  * ``mamba2`` — Mamba2 SSD stack, optionally with a *shared* attention block
    every N layers (zamba2),
  * ``rwkv6``  — RWKV-6 stack.

Layers are stacked and iterated with ``lax.scan`` so the HLO stays compact
(we compile ~60 (arch x shape x mesh) artifacts on one host). Architectures
with a non-uniform per-layer attention window (gemma3) scan over *groups*
(one global + N-1 local layers unrolled inside the body) because the window
size is a static slicing parameter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, BLOCK_ATTN, BLOCK_MAMBA2,
                                BLOCK_RWKV6, FRONTEND_AUDIO, FRONTEND_NONE,
                                FRONTEND_VISION)
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models import scan_utils as SU
from repro.models.params import ParamDef, stack_defs
from repro.models.layers import (COMPUTE_DTYPE, attention_block,
                                 attention_defs, mlp_block, mlp_defs, rmsnorm,
                                 rmsnorm_def)


@dataclass(frozen=True)
class RunFlags:
    """Execution policy knobs (orthogonal to the architecture)."""

    remat: bool = True                       # activation checkpoint each layer
    act_sharding: Any = None                 # NamedSharding for the residual
                                             # stream (sequence parallelism)
    kv_cache_dtype: Any = jnp.bfloat16


DEFAULT_FLAGS = RunFlags()


def _constrain(x, flags: RunFlags):
    if x.ndim != 3:
        return x
    if flags.act_sharding is not None:
        return jax.lax.with_sharding_constraint(x, flags.act_sharding)
    from repro.models.actx import constrain
    return constrain(x, "residual")


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

def _attn_layer_defs(cfg: ArchConfig) -> dict:
    d = {
        "ln_attn": rmsnorm_def(cfg.d_model),
        "attn": attention_defs(cfg),
        "ln_mlp": rmsnorm_def(cfg.d_model),
    }
    if cfg.is_moe:
        d["moe"] = MOE.moe_defs(cfg)
    else:
        d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff)
    return d


def _mamba_layer_defs(cfg: ArchConfig) -> dict:
    return {"ln": rmsnorm_def(cfg.d_model), "mamba": M2.mamba2_defs(cfg)}


def model_defs(cfg: ArchConfig) -> dict:
    """Full ParamDef tree for an architecture."""
    d, v = cfg.d_model, cfg.vocab_size
    defs: dict = {
        "embed": ParamDef((v, d), ("vocab", "embed"), scale=d ** -0.5),
        "final_norm": rmsnorm_def(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"))

    if cfg.block_type == BLOCK_ATTN:
        layer = _attn_layer_defs(cfg)
    elif cfg.block_type == BLOCK_MAMBA2:
        layer = _mamba_layer_defs(cfg)
    elif cfg.block_type == BLOCK_RWKV6:
        layer = R6.rwkv6_defs(cfg)
    else:
        raise ValueError(cfg.block_type)
    defs["layers"] = stack_defs(layer, cfg.n_layers)

    if cfg.shared_attn_every:
        defs["shared_attn"] = {
            "ln_attn": rmsnorm_def(d),
            "attn": attention_defs(cfg),
            "ln_mlp": rmsnorm_def(d),
            "mlp": mlp_defs(d, cfg.d_ff),
        }
    return defs


# ---------------------------------------------------------------------------
# Embedding / frontends
# ---------------------------------------------------------------------------

def embed_input(cfg: ArchConfig, params, batch: dict) -> jax.Array:
    """Token/frontend embedding -> (B, S, d) in compute dtype.

    The audio/vision frontends are stubs per the brief: ``batch`` carries
    precomputed frame/patch embeddings of the right shape.
    """
    emb = params["embed"]
    if cfg.frontend == FRONTEND_AUDIO and "frame_embeds" in batch:
        return batch["frame_embeds"].astype(COMPUTE_DTYPE)
    x = jnp.take(emb, batch["tokens"], axis=0).astype(COMPUTE_DTYPE)
    if cfg.frontend == FRONTEND_VISION and "patch_embeds" in batch:
        p = batch["patch_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(COMPUTE_DTYPE), x[:, p:]], axis=1)
    return x


def lm_logits(cfg: ArchConfig, params, x: jax.Array) -> jax.Array:
    from repro.models.actx import constrain
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                                preferred_element_type=jnp.float32),
                     "logits")


# ---------------------------------------------------------------------------
# Attention stacks
# ---------------------------------------------------------------------------

def _attn_block_body(cfg, flags, lp, x, positions, window,
                     kv_cache=None, cache_index=None, collect_kv=False):
    """One transformer block. Returns (x, aux, kv)."""
    h, kv = attention_block(
        lp["attn"], cfg, rmsnorm(x, lp["ln_attn"], cfg.norm_eps), positions,
        window=window, kv_cache=kv_cache, cache_index=cache_index)
    x = _constrain(x + h, flags)
    aux = jnp.zeros((), jnp.float32)
    y = rmsnorm(x, lp["ln_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        out, aux = MOE.moe_block(lp["moe"], cfg, y)
    else:
        out = mlp_block(lp["mlp"], y)
    x = _constrain(x + out, flags)
    if not collect_kv and kv_cache is None:
        kv = None
    return x, aux, kv


def _maybe_remat(fn, flags: RunFlags):
    if flags.remat:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _split_groups(cfg: ArchConfig):
    """gemma3 grouping: (n_groups, group, remainder_windows)."""
    g = cfg.global_every
    windows = cfg.layer_window_sizes()
    n_groups = cfg.n_layers // g
    rem = cfg.n_layers - n_groups * g
    return n_groups, g, windows[:g], windows[n_groups * g:]


def _tree_slice(tree, start, size):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size,
                                                       axis=0), tree)


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def attn_stack(cfg: ArchConfig, flags: RunFlags, stacked, x, positions,
               kv_caches=None, cache_index=None, collect_kv=False):
    """Run the full attention stack.

    stacked: layer params with leading L dim. kv_caches: None or
    (k (L,B,T,K,D), v (L,B,T,K,D)). Returns (x, aux_sum, kv_out) where
    kv_out is stacked (L, ...) when collect_kv or decoding.
    """
    windows = cfg.layer_window_sizes()
    if not windows:
        # zero-layer variant (dry-run base-cost isolation): run the uniform
        # scan with trip count 0 so output structures (kv caches) survive.
        windows = [0]
    uniform = len(set(windows)) == 1

    def body_for(window):
        def body(carry, scanned):
            x, aux = carry
            if kv_caches is None:
                lp = scanned
                cache = None
            else:
                lp, cache = scanned
            x, a, kv = _attn_block_body(
                cfg, flags, lp, x, positions, window,
                kv_cache=cache, cache_index=cache_index,
                collect_kv=collect_kv)
            if kv is None:
                kv = ()
            return (x, aux + a), kv
        return _maybe_remat(body, flags)

    if uniform:
        xs = stacked if kv_caches is None else (stacked, kv_caches)
        (x, aux), kvs = SU.scan(body_for(windows[0]), (x, 0.0), xs)
        return x, aux, kvs

    # grouped local:global pattern (gemma3): scan over groups of `g` layers
    # with the per-layer windows unrolled inside the body, plus a remainder.
    n_groups, g, group_windows, rem_windows = _split_groups(cfg)

    def reshape_groups(tree):
        return jax.tree.map(
            lambda a: a[: n_groups * g].reshape(n_groups, g, *a.shape[1:]),
            tree)

    def group_body(carry, scanned):
        x, aux = carry
        kvs = []
        for j, w in enumerate(group_windows):
            if kv_caches is None:
                lp = _tree_index(scanned, j)
                cache = None
            else:
                lp = _tree_index(scanned[0], j)
                cache = _tree_index(scanned[1], j)
            x, a, kv = _attn_block_body(
                cfg, flags, lp, x, positions, w, kv_cache=cache,
                cache_index=cache_index, collect_kv=collect_kv)
            aux = aux + a
            kvs.append(kv if kv is not None else ())
        if kvs and kvs[0] != ():
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        else:
            kvs = ()
        return (x, aux), kvs

    head_params = reshape_groups(_tree_slice(stacked, 0, n_groups * g))
    xs = head_params if kv_caches is None else (
        head_params, reshape_groups(_tree_slice(kv_caches, 0, n_groups * g)))
    (x, aux), kvs = SU.scan(_maybe_remat(group_body, flags), (x, 0.0), xs)
    if kvs != ():
        kvs = jax.tree.map(
            lambda a: a.reshape(n_groups * g, *a.shape[2:]), kvs)

    rem_kvs = []
    for j, w in enumerate(rem_windows):
        i = n_groups * g + j
        lp = _tree_index(stacked, i)
        cache = None if kv_caches is None else _tree_index(kv_caches, i)
        x, a, kv = _attn_block_body(
            cfg, flags, lp, x, positions, w, kv_cache=cache,
            cache_index=cache_index, collect_kv=collect_kv)
        aux = aux + a
        rem_kvs.append(kv if kv is not None else ())
    if rem_kvs and rem_kvs[0] != ():
        rem_kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *rem_kvs)
        kvs = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), kvs, rem_kvs)
    return x, aux, kvs


# ---------------------------------------------------------------------------
# SSM stacks (mamba2 / rwkv6), optional shared attention (zamba2)
# ---------------------------------------------------------------------------

def ssm_stack(cfg: ArchConfig, flags: RunFlags, params, x, positions,
              states=None, attn_caches=None, cache_index=None,
              collect_state=False):
    """Mamba2/RWKV6 stack; zamba2 additionally applies the shared attention
    block before layers 0, every, 2*every, ... (unrolled segments around
    scans so attention caches stay compact).

    states: None or stacked per-layer block states. attn_caches: None or
    (k, v) stacked over shared-attn invocations. Returns
    (x, aux, new_states, new_attn_caches).
    """
    stacked = params["layers"]
    is_rwkv = cfg.block_type == BLOCK_RWKV6

    def block(lp, x, st):
        if is_rwkv:
            delta, new_st = R6.rwkv6_block(lp, cfg, x, state=st)
            return _constrain(x + delta, flags), new_st
        h, new_st = M2.mamba2_block(
            lp["mamba"], cfg, rmsnorm(x, lp["ln"], cfg.norm_eps), state=st)
        return _constrain(x + h, flags), new_st

    def scan_segment(x, seg_params, seg_states):
        def body(carry, scanned):
            x = carry
            lp, st = scanned
            x, new_st = block(lp, x, st)
            return x, (new_st if (collect_state or states is not None) else ())
        body = _maybe_remat(body, flags)
        if seg_states is None:
            n = jax.tree.leaves(seg_params)[0].shape[0]
            seg_states = jax.tree.map(
                lambda _: None, jnp.zeros((n,)))  # placeholder
            # build explicit zero states so scan xs have uniform structure
            init = (R6.rwkv6_init_state(cfg, x.shape[0]) if is_rwkv
                    else M2.mamba2_init_state(cfg, x.shape[0]))
            seg_states = jax.tree.map(
                lambda a: jnp.zeros((n, *a.shape), a.dtype), init)
        x, new_states = SU.scan(body, x, (seg_params, seg_states))
        return x, new_states

    aux = jnp.zeros((), jnp.float32)
    if not cfg.shared_attn_every:
        st = states
        if st is None and not collect_state:
            pass
        x, new_states = scan_segment(x, stacked, states)
        return x, aux, new_states, ()

    # zamba2: the shared attention block runs before layers 0, every,
    # 2*every, ... Since its weights are SHARED, full segments (attn +
    # `every` mamba layers) are identical programs -> scan over segments
    # with the mamba params reshaped (n_full, every, ...); only the
    # remainder segment is unrolled. This keeps the 81-layer HLO at
    # ~one-segment size (the naive unrolled form took >25min to compile).
    every = cfg.shared_attn_every
    n_full = cfg.n_layers // every
    rem = cfg.n_layers - n_full * every
    sp = params["shared_attn"]
    want_state = collect_state or states is not None
    want_kv = collect_state or attn_caches is not None

    def attn_and_mlp(x, cache):
        h, kv = attention_block(
            sp["attn"], cfg, rmsnorm(x, sp["ln_attn"], cfg.norm_eps),
            positions, window=cfg.sliding_window,
            kv_cache=cache, cache_index=cache_index)
        x = _constrain(x + h, flags)
        x = _constrain(
            x + mlp_block(sp["mlp"], rmsnorm(x, sp["ln_mlp"], cfg.norm_eps)),
            flags)
        return x, kv

    def zero_states(n):
        init = (R6.rwkv6_init_state(cfg, x.shape[0]) if is_rwkv
                else M2.mamba2_init_state(cfg, x.shape[0]))
        return jax.tree.map(
            lambda a: jnp.zeros((n, *a.shape), a.dtype), init)

    def reshape_seg(tree, n, e):
        return jax.tree.map(
            lambda a: a[: n * e].reshape(n, e, *a.shape[1:]), tree)

    head = reshape_seg(_tree_slice(stacked, 0, n_full * every), n_full, every)
    head_states = (reshape_seg(_tree_slice(states, 0, n_full * every),
                               n_full, every) if states is not None
                   else reshape_seg(zero_states(n_full * every),
                                    n_full, every))

    def seg_body(carry, scanned):
        x, aux = carry
        seg_params, seg_states, seg_cache = scanned
        x, kv = attn_and_mlp(x, seg_cache if attn_caches is not None
                             else None)
        new_sts = []
        for j in range(every):
            x, st = block(_tree_index(seg_params, j), x,
                          _tree_index(seg_states, j) if states is not None
                          else None)
            new_sts.append(st if want_state else ())
        outs = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_sts)
                if want_state else (),
                kv if (kv is not None and want_kv) else ())
        return (x, aux), outs

    seg_caches = (_tree_slice(attn_caches, 0, n_full)
                  if attn_caches is not None else jnp.zeros((n_full, 0)))
    xs = (head, head_states, seg_caches)
    (x, aux), (seg_new_states, seg_kvs) = SU.scan(
        _maybe_remat(seg_body, flags), (x, aux), xs)
    new_states_parts = []
    if want_state and seg_new_states != ():
        new_states_parts.append(jax.tree.map(
            lambda a: a.reshape(n_full * every, *a.shape[2:]),
            seg_new_states))
    new_kvs = [seg_kvs] if (want_kv and seg_kvs != ()) else []

    if rem:
        cache = (_tree_index(attn_caches, n_full)
                 if attn_caches is not None else None)
        x, kv = attn_and_mlp(x, cache)
        if want_kv and kv is not None:
            new_kvs.append(jax.tree.map(lambda a: a[None], kv))
        seg_params = _tree_slice(stacked, n_full * every, rem)
        seg_states = (None if states is None
                      else _tree_slice(states, n_full * every, rem))
        x, seg_new = scan_segment(x, seg_params, seg_states)
        if want_state and seg_new != ():
            new_states_parts.append(seg_new)

    new_states = (jax.tree.map(lambda *xs: jnp.concatenate(xs),
                               *new_states_parts)
                  if new_states_parts else ())
    new_kv = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_kvs)
              if new_kvs else ())
    return x, aux, new_states, new_kv


# ---------------------------------------------------------------------------
# Public API: forward / prefill / decode
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params, batch: dict,
            flags: RunFlags = DEFAULT_FLAGS):
    """Training forward: returns (logits (B,S,V) fp32, aux_loss)."""
    x = embed_input(cfg, params, batch)
    s = x.shape[1]
    positions = jnp.arange(s)
    if cfg.block_type == BLOCK_ATTN:
        x, aux, _ = attn_stack(cfg, flags, params["layers"], x, positions)
    else:
        x, aux, _, _ = ssm_stack(cfg, flags, params, x, positions)
    return lm_logits(cfg, params, x), aux


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               flags: RunFlags = DEFAULT_FLAGS):
    """Zero-initialized serving cache (shape donor for decode dry-runs)."""
    hd = cfg.resolved_head_dim
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.block_type == BLOCK_ATTN:
        kv_shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, hd)
        cache["kv"] = (jnp.zeros(kv_shape, flags.kv_cache_dtype),
                       jnp.zeros(kv_shape, flags.kv_cache_dtype))
    else:
        init = (R6.rwkv6_init_state(cfg, batch_size)
                if cfg.block_type == BLOCK_RWKV6
                else M2.mamba2_init_state(cfg, batch_size))
        cache["state"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), init)
        if cfg.shared_attn_every:
            n_seg = -(-cfg.n_layers // cfg.shared_attn_every)
            kv_shape = (n_seg, batch_size, max_len, cfg.n_kv_heads, hd)
            cache["attn_kv"] = (jnp.zeros(kv_shape, flags.kv_cache_dtype),
                                jnp.zeros(kv_shape, flags.kv_cache_dtype))
    return cache


def prefill(cfg: ArchConfig, params, batch: dict, max_len: int,
            flags: RunFlags = DEFAULT_FLAGS):
    """Prefill: run the prompt, return (last-token logits (B,1,V), cache)."""
    x = embed_input(cfg, params, batch)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)
    cache = {"pos": jnp.asarray(s, jnp.int32)}
    if cfg.block_type == BLOCK_ATTN:
        x, _, kvs = attn_stack(cfg, flags, params["layers"], x, positions,
                               collect_kv=True)
        # pad caches out to max_len
        def pad(a):
            pad_len = max_len - a.shape[2]
            return jnp.pad(a, ((0, 0), (0, 0), (0, pad_len), (0, 0), (0, 0))
                           ).astype(flags.kv_cache_dtype)
        cache["kv"] = jax.tree.map(pad, kvs)
    else:
        x, _, states, kvs = ssm_stack(cfg, flags, params, x, positions,
                                      collect_state=True)
        cache["state"] = states
        if cfg.shared_attn_every:
            def pad(a):
                pad_len = max_len - a.shape[2]
                return jnp.pad(a, ((0, 0), (0, 0), (0, pad_len), (0, 0),
                                   (0, 0))).astype(flags.kv_cache_dtype)
            cache["attn_kv"] = jax.tree.map(pad, kvs)
    logits = lm_logits(cfg, params, x[:, -1:])
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache: dict, tokens: jax.Array,
                flags: RunFlags = DEFAULT_FLAGS):
    """One decode step: tokens (B, 1) at position cache['pos'].

    Returns (logits (B,1,V), new_cache)."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    new_cache = {"pos": pos + 1}
    if cfg.block_type == BLOCK_ATTN:
        x, _, kvs = attn_stack(cfg, flags, params["layers"], x, positions,
                               kv_caches=cache["kv"], cache_index=pos)
        new_cache["kv"] = kvs
    else:
        x, _, states, kvs = ssm_stack(
            cfg, flags, params, x, positions, states=cache["state"],
            attn_caches=cache.get("attn_kv"), cache_index=pos)
        new_cache["state"] = states
        if cfg.shared_attn_every:
            new_cache["attn_kv"] = kvs
    return lm_logits(cfg, params, x), new_cache
