"""Parameter-definition system.

Models declare their parameters as trees of :class:`ParamDef` (shape + init +
logical axis names). From one declaration we derive:

  * ``init_params``   — PRNG-keyed materialization (pure jnp, usable under
    ``jax.eval_shape`` for allocation-free dry-runs),
  * ``param_specs``   — ``PartitionSpec`` tree for a given mesh, resolved from
    logical axis names with divisibility-aware fallback,
  * ``abstract_params`` — ``ShapeDtypeStruct`` tree.

Logical axes and their mesh-axis candidates (first divisible dim in priority
order wins the ``model`` axis; optionally a second dim is sharded over the
``data``(+``pod``) axes for FSDP/ZeRO-style parameter sharding):

  experts > vocab > heads > kv_heads > ff > dinner > embed   -> "model"
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Priority order for assigning the tensor-parallel ("model") mesh axis.
MODEL_AXIS_PRIORITY = (
    "experts", "vocab", "heads", "kv_heads", "ff", "dinner", "state", "embed",
)
# Logical axes eligible for FSDP ("data"-axis) parameter sharding, i.e. large
# dims that remain after the model axis is assigned.
FSDP_AXIS_CANDIDATES = (
    "embed", "ff", "dinner", "vocab", "heads", "kv_heads", "experts",
)
# Axes that must never be sharded (stacking / small structural dims).
UNSHARDED = ("layers", "chunk", "window", None)


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]           # logical name per dim
    init: str = "normal"                   # normal | zeros | ones | constant
    scale: float | None = None             # normal: stddev (None => 1/sqrt fan_in)
    constant: float = 0.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "constant":
            return jnp.full(self.shape, self.constant, self.dtype)
        if self.init == "normal":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale if self.scale is not None else fan_in ** -0.5
            return (std * jax.random.normal(key, self.shape)).astype(self.dtype)
        raise ValueError(self.init)


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(defs, n: int):
    """Add a leading stacked-layers dim of size ``n`` to every ParamDef."""
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(n, *d.shape), axes=("layers", *d.axes)),
        defs,
        is_leaf=is_param_def,
    )


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------

def resolve_spec(d: ParamDef, axis_sizes: dict[str, int], fsdp_axes: tuple[str, ...] = ()) -> P:
    """Map logical axes to mesh axes for one parameter."""
    model_size = axis_sizes.get("model", 1)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= axis_sizes.get(a, 1)

    assignment: dict[int, Any] = {}

    # 1) model axis -> highest-priority divisible dim
    if model_size > 1:
        ranked = sorted(
            (i for i, ax in enumerate(d.axes) if ax in MODEL_AXIS_PRIORITY),
            key=lambda i: MODEL_AXIS_PRIORITY.index(d.axes[i]),
        )
        for i in ranked:
            if d.shape[i] % model_size == 0 and d.shape[i] >= model_size:
                assignment[i] = "model"
                break

    # 2) fsdp (data/pod) axis -> largest remaining eligible dim
    if fsdp_size > 1 and fsdp_axes:
        cands = [
            i for i, ax in enumerate(d.axes)
            if ax in FSDP_AXIS_CANDIDATES and i not in assignment
            and d.shape[i] % fsdp_size == 0 and d.shape[i] >= fsdp_size
        ]
        if cands:
            i = max(cands, key=lambda i: d.shape[i])
            assignment[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]

    return P(*(assignment.get(i) for i in range(len(d.shape))))


def param_specs(defs, axis_sizes: dict[str, int], fsdp_axes: tuple[str, ...] = ()):
    return jax.tree.map(
        lambda d: resolve_spec(d, axis_sizes, fsdp_axes), defs, is_leaf=is_param_def
    )


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def init_params(defs, key: jax.Array):
    """Materialize a ParamDef tree; per-leaf keys derive from the tree path so
    the result is insertion-order independent.  The path digest must be
    stable ACROSS processes (crc32, not the salted builtin ``hash``), or a
    supervisor restart / replay oracle would initialize a different model
    from the same seed."""

    def leaf(path, d: ParamDef):
        digest = zlib.crc32(_path_str(path).encode()) % (2**31)
        k = jax.random.fold_in(key, digest)
        return d.materialize(k)

    return jax.tree_util.tree_map_with_path(leaf, defs, is_leaf=is_param_def)


def abstract_params(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_param_def
    )


def count_params(defs) -> int:
    import math
    leaves = jax.tree.leaves(defs, is_leaf=is_param_def)
    return sum(math.prod(d.shape) for d in leaves)
