"""Sequential (one-timestep-at-a-time) reference recurrences.

The production Mamba2/RWKV6 blocks use chunked parallel forms (MXU-friendly,
compile-compact); these step-by-step references implement the *defining*
recurrences directly, so tests can assert the chunked algebra is exactly the
recurrence — the strongest correctness check an SSM layer can have.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(xh, a, bmat, cmat, state0=None):
    """Mamba2 SSD, stepwise:  S_t = exp(a_t) S_{t-1} + x_t (x) B_t,
    y_t = S_t @ C_t.   Shapes as ssd_chunked."""
    b, t, h, hd = xh.shape
    n = bmat.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((b, h, hd, n), jnp.float32)

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp                        # (b,h,hd),(b,h),(b,n)
        decay = jnp.exp(a_t)[:, :, None, None]
        state = decay * state + jnp.einsum(
            "bhd,bn->bhdn", x_t.astype(jnp.float32), b_t.astype(jnp.float32))
        y = jnp.einsum("bhdn,bn->bhd", state, c_t.astype(jnp.float32))
        return state, y

    xs = (xh.swapaxes(0, 1), a.swapaxes(0, 1), bmat.swapaxes(0, 1),
          cmat.swapaxes(0, 1))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(xh.dtype), final


def wkv6_sequential(r, k, v, log_w, u, state0=None):
    """RWKV6 WKV, stepwise:  o_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t);
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t.   Shapes as wkv6_chunked."""
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = (x.astype(jnp.float32) for x in inp)  # (b,h,n)
        kv = jnp.einsum("bhn,bhm->bhnm", k_t, v_t)
        o = jnp.einsum("bhn,bhnm->bhm", r_t,
                       state + u[None, :, :, None] * kv)
        state = jnp.exp(w_t)[..., None] * state + kv
        return state, o

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_w.swapaxes(0, 1))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), final
