"""Sharding-rule unit tests (no multi-device mesh needed: specs are pure
functions of shapes + axis sizes)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.params import ParamDef, param_specs, resolve_spec
from repro.models.transformer import model_defs

AX = {"model": 16, "data": 16}


def spec_of(d):
    return resolve_spec(d, AX)


def test_ff_sharded_on_model():
    d = ParamDef((2048, 6144), ("embed", "ff"))
    assert spec_of(d) == P(None, "model")


def test_vocab_priority_over_embed():
    d = ParamDef((131072, 5120), ("vocab", "embed"))
    assert spec_of(d) == P("model", None)


def test_nondivisible_vocab_falls_back_to_embed():
    d = ParamDef((92553, 2048), ("vocab", "embed"))
    assert spec_of(d) == P(None, "model")


def test_kv_heads_replicated_when_non_divisible():
    cfg = get_config("mistral-nemo-12b")  # kv=8 < 16
    from repro.models.layers import attention_defs
    specs = {k: resolve_spec(v, AX) for k, v in attention_defs(cfg).items()}
    assert specs["wk"] == P(None, None, None)       # replicated
    assert specs["wq"] == P(None, "model", None)    # heads sharded


def test_kv_heads_sharded_when_divisible():
    cfg = get_config("zamba2-7b")  # kv=32
    from repro.models.layers import attention_defs
    specs = {k: resolve_spec(v, AX) for k, v in attention_defs(cfg).items()}
    assert specs["wk"] == P(None, "model", None)


def test_experts_sharded_when_divisible():
    cfg = get_config("moonshot-v1-16b-a3b")  # 64 experts
    d = ParamDef((64, 2048, 1408), ("experts", "embed", "ff"))
    assert spec_of(d) == P("model", None, None)


def test_experts_fall_to_ff_when_non_divisible():
    d = ParamDef((8, 4096, 14336), ("experts", "embed", "ff"))  # mixtral
    assert spec_of(d) == P(None, None, "model")


def test_fsdp_shards_largest_remaining_dim():
    d = ParamDef((8, 4096, 14336), ("experts", "embed", "ff"))
    s = resolve_spec(d, AX, fsdp_axes=("data",))
    assert s == P(None, "data", "model")


def test_layer_stacked_dim_never_sharded():
    cfg = get_config("qwen3-1.7b")
    specs = param_specs(model_defs(cfg), AX)
    for leaf in jax.tree.leaves(specs["layers"],
                                is_leaf=lambda x: isinstance(x, P)):
        assert len(leaf) == 0 or leaf[0] is None


def test_all_full_configs_have_some_model_sharding():
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        specs = param_specs(model_defs(cfg), AX)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert any("model" in tuple(s) for s in leaves), arch
