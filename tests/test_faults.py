"""repro.faults invariants: the plan DSL replays deterministically, the
injectors fire exactly the scheduled faults, and every degradation path
degrades *gracefully*:

  * torn checkpoints are skipped on resume instead of crashing it,
  * a poisoned gradient step is skipped (params bitwise-unchanged), and on
    the async path poison is zeroed before it can reach the delay rings,
  * the serving replica refuses non-finite publishes and keeps serving the
    last healthy snapshot,
  * the scheduler quarantines NaN-logit requests (evict + requeue once,
    fail on the second offense) and never leaks a page doing it,
  * page-pool exhaustion turns into retry-after backpressure, not loss.
"""
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core.delivery import DROPPED
from repro.faults import (FAULT_KINDS, FaultEvent, FaultPlan,
                          ServeFaultInjector, TrainFaultInjector)
from repro.serve import (ContinuousScheduler, PagedCacheConfig,
                         PageAllocator, ParamReplica, Request)


# ---------------------------------------------------------------------------
# the plan DSL
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="meteor")
    with pytest.raises(ValueError):
        FaultEvent(step=-1, kind="kill")
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="crash", duration=-2)
    assert FaultEvent(step=0, kind="crash", duration=0).duration == 0


def test_plan_queries():
    plan = FaultPlan(events=(
        FaultEvent(step=3, kind="grad_poison"),
        FaultEvent(step=3, kind="ckpt_io"),
        FaultEvent(step=7, kind="crash", worker=1, duration=0),
    ))
    assert {e.kind for e in plan.at(3)} == {"grad_poison", "ckpt_io"}
    assert plan.at(3, "ckpt_io")[0].kind == "ckpt_io"
    assert plan.at(5) == []
    assert plan.kinds() == {"grad_poison", "ckpt_io", "crash"}
    assert plan.has_poison and plan.has_tau_events
    assert plan.max_step == 7
    empty = FaultPlan()
    assert not empty.has_poison and not empty.has_tau_events
    assert empty.max_step == 0


def test_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(events=(
        FaultEvent(step=6, kind="kill", on_attempt=1),
        FaultEvent(step=2, kind="grad_poison", param=1.0),
        FaultEvent(step=4, kind="delay", worker=2, duration=3),
    ), seed=9)
    assert FaultPlan.from_json(plan.to_json()) == plan
    # load() takes inline JSON or a path interchangeably
    assert FaultPlan.load(plan.to_json()) == plan
    p = tmp_path / "plan.json"
    plan.save(str(p))
    assert FaultPlan.load(str(p)) == plan
    # dict events coerce (hand-written JSON-ish plans)
    assert FaultPlan(events=({"step": 1, "kind": "kill"},)).events[0] == \
        FaultEvent(step=1, kind="kill")


def test_plan_random_is_pure_in_seed():
    a = FaultPlan.random(5, steps=40, workers=4)
    b = FaultPlan.random(5, steps=40, workers=4)
    assert a == b
    assert a != FaultPlan.random(6, steps=40, workers=4)
    assert all(e.kind in FAULT_KINDS and 0 <= e.step < 40 for e in a.events)
    steps = [e.step for e in a.events]
    assert steps == sorted(steps)


def test_plan_cli_authoring(tmp_path, monkeypatch):
    from repro.faults import plan as plan_mod

    out = tmp_path / "p.json"
    monkeypatch.setattr(sys, "argv", [
        "plan", "--out", str(out), "--kill-at", "6", "--kill-attempt", "0",
        "--poison-at", "3", "--ckpt-io-at", "8",
        "--crash", "1@4:0", "--rejoin", "1@9", "--delay", "0@2:3"])
    plan_mod._main()
    plan = FaultPlan.load(str(out))
    assert plan.kinds() == {"kill", "grad_poison", "ckpt_io", "crash",
                            "rejoin", "delay"}
    crash = plan.at(4, "crash")[0]
    assert crash.worker == 1 and crash.duration == 0
    assert plan.at(9, "rejoin")[0].worker == 1


# ---------------------------------------------------------------------------
# the training-side injector (host half; the jit half is tested below)
# ---------------------------------------------------------------------------

def test_train_injector_loss_scale():
    plan = FaultPlan(events=(
        FaultEvent(step=2, kind="grad_poison"),
        FaultEvent(step=4, kind="grad_poison", param=1.0),
    ))
    inj = TrainFaultInjector(plan)
    assert inj.has_poison
    assert inj.loss_scale(0) == 1.0 and inj.loss_scale(3) == 1.0
    assert np.isnan(inj.loss_scale(2))
    assert np.isposinf(inj.loss_scale(4))
    assert inj.poisoned_steps == 2


def test_train_injector_ckpt_io_and_kill_gating():
    plan = FaultPlan(events=(
        FaultEvent(step=8, kind="ckpt_io"),
        FaultEvent(step=5, kind="kill", on_attempt=1),
    ))
    inj = TrainFaultInjector(plan, attempt=0)
    inj.check_ckpt_io(4)                     # nothing scheduled: no-op
    with pytest.raises(OSError):
        inj.check_ckpt_io(8)
    assert inj.ckpt_errors == 1
    # the kill is scheduled for attempt 1; on attempt 0 it must NOT fire
    # (if it did, this process would be gone)
    inj.maybe_kill(5)


# ---------------------------------------------------------------------------
# checkpoint torn-write recovery (satellite: sidecar-first atomicity)
# ---------------------------------------------------------------------------

def test_latest_step_skips_torn_checkpoint(tmp_path):
    tree = {"w": np.arange(3, dtype=np.float32)}
    save_checkpoint(str(tmp_path), 4, tree)
    save_checkpoint(str(tmp_path), 8, tree)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # clean dir: no warnings
        assert latest_step(str(tmp_path)) == 8
    # lose step 8's sidecar (pre-ordering checkpoint / filesystem loss)
    (tmp_path / "step_00000008.npz.treedef").unlink()
    with pytest.warns(UserWarning, match="torn write"):
        assert latest_step(str(tmp_path)) == 4
    with pytest.raises(FileNotFoundError, match="latest_step"):
        load_checkpoint(str(tmp_path), 8)
    restored = load_checkpoint(str(tmp_path), 4)
    np.testing.assert_array_equal(restored["w"], tree["w"])
    # a corrupt (unpicklable) sidecar is just as torn as a missing one
    (tmp_path / "step_00000004.npz.treedef").write_bytes(b"\x00garbage")
    with pytest.warns(UserWarning, match="torn write"):
        assert latest_step(str(tmp_path)) is None
    assert latest_step(str(tmp_path / "nope")) is None


def test_orphan_sidecar_is_invisible(tmp_path):
    """The crash window of the sidecar-first ordering: a kill between the
    two replaces leaves a sidecar with no ``.npz`` — resume never sees it."""
    tree = {"w": np.zeros(2, np.float32)}
    save_checkpoint(str(tmp_path), 3, tree)
    (tmp_path / "step_00000003.npz").unlink()   # the .npz never landed
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert latest_step(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# the skip-step guard on the real train step
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMDataset
    from repro.models import transformer as TF
    from repro.models.params import init_params
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b").reduced()
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    opt = momentum(1e-2, 0.9)
    data = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=0)
    return cfg, flags, params, opt, data


def _scaled(batch, scale):
    return dict(batch, loss_scale=np.full((4,), scale, np.float32))


def test_guarded_step_neutral_scale_matches_unguarded(tiny):
    from repro.dist.train import make_train_step

    cfg, flags, params, opt, data = tiny
    plain = jax.jit(make_train_step(cfg, opt, flags))
    guarded = jax.jit(make_train_step(cfg, opt, flags, skip_nonfinite=True))
    p_a, s_a, m_a = plain(params, opt.init(params), data.batch(0))
    p_b, s_b, m_b = guarded(params, opt.init(params),
                            _scaled(data.batch(0), 1.0))
    assert float(m_b["nonfinite"]) == 0.0
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               atol=1e-6)
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_guarded_step_skips_poisoned_batch(tiny):
    from repro.dist.train import make_train_step

    cfg, flags, params, opt, data = tiny
    step = jax.jit(make_train_step(cfg, opt, flags, skip_nonfinite=True))
    opt_state = opt.init(params)
    # poisoned step: loss is NaN, but params/opt state are bitwise frozen
    p1, s1, m = step(params, opt_state, _scaled(data.batch(0), np.nan))
    assert not np.isfinite(float(m["loss"]))
    assert float(m["nonfinite"]) == 1.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the next clean step trains normally from the preserved state
    p2, s2, m2 = step(p1, s1, _scaled(data.batch(1), 1.0))
    assert float(m2["nonfinite"]) == 0.0 and np.isfinite(float(m2["loss"]))
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert delta > 0


def test_async_engine_contains_poison(tiny):
    """skip_nonfinite on the async path: a poisoned local gradient is
    zeroed BEFORE it reaches the delay rings (and before compression/EF),
    so later steps never replay it — params stay finite forever."""
    from repro.dist import sharding as SH
    from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                         make_async_train_step)
    from repro.jax_compat import make_mesh
    from repro.models import transformer as TF
    from repro.models.params import param_specs

    cfg, flags, params, opt, data = tiny
    mesh = make_mesh((1, 1), ("data", "model"))
    pspecs = param_specs(TF.model_defs(cfg), SH.axis_sizes(mesh))
    acfg = AsyncConfig(tau_max=2, schedule="uniform", seed=1,
                       skip_nonfinite=True)
    state = init_async_state(acfg, mesh, params)
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    opt_state = opt.init(params)
    for t in range(6):
        scale = np.nan if t == 2 else 1.0
        params, opt_state, state, m = step(params, opt_state, state,
                                           _scaled(data.batch(t), scale))
        assert float(m["nonfinite"]) == (1.0 if t == 2 else 0.0)
        if t != 2:
            assert np.isfinite(float(m["loss"]))
        assert all(bool(jnp.all(jnp.isfinite(leaf)))
                   for leaf in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# replica publish refusal
# ---------------------------------------------------------------------------

def _vparams(v: float):
    return {"w": jnp.full((3,), float(v), jnp.float32)}


def test_replica_refuses_nonfinite_publish():
    rep = ParamReplica(_vparams(0), 2)
    bad = {"w": jnp.asarray([1.0, np.nan, 2.0], jnp.float32)}
    assert rep.publish(bad) is None
    assert rep.refused == 1 and rep.latest_version == 0
    # still serving the healthy bootstrap snapshot
    assert float(rep.serving_params()["w"][0]) == 0.0
    # recovery: the next finite publish advances normally
    assert rep.publish(_vparams(1)) == 1
    assert rep.latest_version == 1 and rep.refused == 1
    with pytest.raises(ValueError, match="non-finite"):
        ParamReplica({"w": jnp.asarray([np.inf], jnp.float32)}, 1)


# ---------------------------------------------------------------------------
# scheduler quarantine + backpressure over a fake engine (real allocator)
# ---------------------------------------------------------------------------

class PoisonableEngine:
    """test_serve's FakeEngine surface plus the quarantine verbs
    (``nonfinite_rids`` / ``poison_kv``).  Poison lives with the request's
    pages: ``finish`` frees both, so a requeued request restarts clean —
    exactly the real engine's semantics."""

    def __init__(self, pcfg: PagedCacheConfig, sticky: bool = False):
        self.pcfg = pcfg
        self.alloc = PageAllocator(pcfg)
        self.active = np.zeros(pcfg.max_requests, bool)
        self._slot_of: dict = {}
        self.steps = 0
        self.poisoned: set = set()
        self.sticky = sticky          # re-poison on readmission (2nd offense)
        self._ever_poisoned: set = set()

    def has_slot(self) -> bool:
        return int(self.active.sum()) < self.pcfg.max_requests

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        total = prompt_len + max_new
        return self.has_slot() and self.alloc.can_alloc(
            self.pcfg.pages_needed(total))

    def start(self, rid, prompt, max_new):
        pages = self.alloc.alloc(rid, self.pcfg.pages_needed(
            len(prompt) + max_new))
        assert pages is not None
        slot = int(np.flatnonzero(~self.active)[0])
        self.active[slot] = True
        self._slot_of[rid] = slot
        if self.sticky and rid in self._ever_poisoned:
            self.poisoned.add(rid)
        return np.asarray([9000 + rid], np.int32)

    def step(self):
        self.steps += 1
        return np.arange(self.pcfg.max_requests, dtype=np.int32) * 1000 \
            + self.steps

    def nonfinite_rids(self) -> list:
        return [rid for rid in sorted(self.poisoned)
                if rid in self._slot_of]

    def poison_kv(self, rid) -> None:
        self.poisoned.add(rid)
        self._ever_poisoned.add(rid)

    def finish(self, rid) -> None:
        slot = self._slot_of.pop(rid)
        self.alloc.free(rid)
        self.active[slot] = False
        self.poisoned.discard(rid)    # poison dies with the freed pages

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]


def _pcfg():
    return PagedCacheConfig(page_size=4, num_pages=4, max_requests=2,
                            max_pages_per_seq=2)


def test_scheduler_quarantines_once_then_recovers():
    engine = PoisonableEngine(_pcfg())
    sched = ContinuousScheduler(engine, quarantine=True)
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new=3)
            for i in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    engine.poison_kv(0)               # decode hit NaN logits for rid 0
    while sched.queue or sched._live:
        sched.step()
    toks = sched.drain()
    assert sched.quarantined == 1 and sched.failed == 0
    assert sorted(toks) == [0, 1, 2]  # the victim completed on retry
    assert len(toks[0]) == 3
    engine.alloc.check()
    assert engine.alloc.n_free == engine.pcfg.num_pages
    assert sched.stats()["quarantined"] == 1


def test_scheduler_fails_twice_poisoned_request():
    engine = PoisonableEngine(_pcfg(), sticky=True)
    sched = ContinuousScheduler(engine, quarantine=True)
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new=3)
            for i in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    engine.poison_kv(0)               # this one re-poisons every admission
    while sched.queue or sched._live:
        sched.step()
    toks = sched.drain()
    assert sched.quarantined == 1 and sched.failed == 1
    assert sched.completions[0].failed and sched.completions[0].tokens is None
    assert sorted(toks) == [1, 2]     # failed rid excluded, others clean
    engine.alloc.check()              # no page leaked through the eviction


def test_run_retry_after_completes_under_backpressure():
    engine = PoisonableEngine(_pcfg())
    sched = ContinuousScheduler(engine, queue_limit=1)
    trace = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new=2,
                     arrival=0) for i in range(6)]
    toks = sched.run(trace)
    assert sorted(toks) == list(range(6))      # nothing silently dropped
    st = sched.stats()
    assert st["rejected"] > 0 and st["resubmitted"] == st["rejected"]
    assert 0 < st["rejected_frac"] < 1
    assert st["submitted"] == 6 + st["resubmitted"]
    # rejected arrivals pay their wait: latency includes the backpressure
    assert max(sched.latencies) > min(sched.latencies)
    engine.alloc.check()


def test_submit_sets_retry_after_hint():
    sched = ContinuousScheduler(PoisonableEngine(_pcfg()), queue_limit=2)
    for i in range(5):
        sched.submit(Request(rid=i, prompt=np.zeros(1, np.int32), max_new=1))
    assert sched.rejected == 3 and sched.retry_after >= 1
    assert sched.stats()["rejected_frac"] == pytest.approx(3 / 5)


# ---------------------------------------------------------------------------
# the serve-side injector
# ---------------------------------------------------------------------------

def test_serve_injector_page_exhaust_backpressure():
    engine = PoisonableEngine(_pcfg())
    plan = FaultPlan(events=(
        FaultEvent(step=0, kind="page_exhaust", duration=3),))
    inj = ServeFaultInjector(plan, engine)
    sched = ContinuousScheduler(engine, on_tick=inj.on_tick)
    toks = sched.run([Request(rid=0, prompt=np.zeros(2, np.int32),
                              max_new=2, arrival=0)])
    assert inj.exhausted == 1
    assert len(toks[0]) == 2
    # admission had to wait for the hold to release at tick 3
    assert sched.completions[0].admitted >= 3
    inj.release_all()
    engine.alloc.check()
    assert engine.alloc.n_free == engine.pcfg.num_pages


def test_serve_injector_logit_poison_drives_quarantine():
    engine = PoisonableEngine(_pcfg())
    plan = FaultPlan(events=(
        FaultEvent(step=1, kind="logit_poison"),))
    inj = ServeFaultInjector(plan, engine)
    sched = ContinuousScheduler(engine, quarantine=True,
                                on_tick=inj.on_tick)
    toks = sched.run([Request(rid=i, prompt=np.zeros(2, np.int32),
                              max_new=3, arrival=0) for i in range(2)])
    assert inj.poisoned == 1
    assert sched.quarantined == 1 and sched.failed == 0
    assert sorted(toks) == [0, 1]
    inj.release_all()
    engine.alloc.check()


def test_serve_injector_partial_exhaust_releases_on_time():
    engine = PoisonableEngine(_pcfg())
    plan = FaultPlan(events=(
        FaultEvent(step=0, kind="page_exhaust", duration=2, param=3.0),))
    inj = ServeFaultInjector(plan, engine)
    sched = ContinuousScheduler(engine, on_tick=inj.on_tick)
    assert engine.alloc.n_free == 4
    sched.step()                      # tick 0: hold 3 of 4 pages
    assert engine.alloc.n_free == 1
    sched.step()
    sched.step()                      # tick 2: hold expires on entry
    assert engine.alloc.n_free == 4
    inj.release_all()
    engine.alloc.check()


# ---------------------------------------------------------------------------
# DROPPED sanity shared with the delivery tests
# ---------------------------------------------------------------------------

def test_apply_to_taus_bounds():
    plan = FaultPlan.random(7, steps=20, workers=3, kinds=("crash", "rejoin",
                                                           "delay", "drop"))
    base = np.zeros((20, 3), np.int32)
    out = plan.apply_to_taus(base, tau_max=4)
    assert out.dtype == np.int32
    live = out[out != DROPPED]
    assert live.size == 0 or (live.min() >= 0 and live.max() <= 4)
