"""Fused simulator-step path: kernel / engine / grid parity.

Three layers of checks:
  * op level — the Pallas kernels (interpret mode on CPU) against the fused
    jnp oracle in `kernels/sim_step/ref.py`, element-for-element,
  * engine level — ``simulate(..., fused=True)`` against the unfused scan
    step AND the numpy oracle, step-for-step, for every fused kind,
  * grid level — ``simulate_grid`` against a Python loop of
    ``simulate_sweep`` calls (same trajectories, one compiled program).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.problems import MLPClassification, Quadratic
from repro.core.sim import Relaxation, simulate, simulate_grid, simulate_sweep
from repro.kernels import sim_step
from repro.kernels.sim_step import kernel as K
from repro.kernels.sim_step import ref as R

P, T, ALPHA, DIM = 8, 60, 0.02, 32

FUSED_CASES = [
    ("sync", {}),
    ("crash", dict(f=3)),
    ("crash_subst", dict(f=3)),
    ("elastic_variance", dict(drop_prob=0.3)),
]


@pytest.fixture(scope="module")
def prob():
    return Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)


@pytest.fixture(scope="module")
def x0():
    return np.ones(DIM, np.float32) * 2.0


# ---------------------------------------------------------------------------
# op level: Pallas kernel (interpret) vs fused jnp oracle
# ---------------------------------------------------------------------------

def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("d,block_d", [(256, 128), (100, 256)],
                         ids=["tiled", "odd-d-single-block"])
def test_delivery_kernel_matches_ref(d, block_d):
    rng = np.random.default_rng(0)
    p = 8
    v, x, xs = _rand(rng, p, d), _rand(rng, 1, d), _rand(rng, 1, d)
    a, n = _rand(rng, d, d), _rand(rng, p, d)
    u = _rand(rng, 1 + p, p)
    got = K.delivery_step(v, x, a, xs, n, u, block_d=block_d, interpret=True)
    want = R.delivery_step_ref(v, x, a, xs, n, u)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-4)


def test_delivery_kernel_defer_matches_ref():
    rng = np.random.default_rng(1)
    p, d = 8, 256
    v, x, xs = _rand(rng, p, d), _rand(rng, 1, d), _rand(rng, 1, d)
    a, n, defer = _rand(rng, d, d), _rand(rng, p, d), _rand(rng, p, d)
    u = _rand(rng, 1 + 2 * p, p)
    got = K.delivery_step(v, x, a, xs, n, u, defer, block_d=128,
                          has_defer=True, interpret=True)
    want = R.delivery_step_ref(v, x, a, xs, n, u, defer)
    assert len(got) == len(want) == 3
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-4)


def test_sync_kernel_matches_ref():
    rng = np.random.default_rng(2)
    d = 256
    x, xs, nsum = _rand(rng, 1, d), _rand(rng, 1, d), _rand(rng, 1, d)
    a = _rand(rng, d, d)
    got = K.sync_step(x, a, xs, nsum, jnp.full((1, 1), 0.03, jnp.float32),
                      block_d=128, interpret=True)
    want = R.sync_step_ref(x, a, xs, nsum, jnp.float32(0.03))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# engine level: fused vs unfused scan vs numpy oracle, step-for-step
# ---------------------------------------------------------------------------

def _assert_parity(a, b):
    np.testing.assert_allclose(a.gap2_over_alpha2, b.gap2_over_alpha2,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(a.losses, b.losses, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(a.x_final, b.x_final, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("kind,kw", FUSED_CASES,
                         ids=[c[0] for c in FUSED_CASES])
def test_fused_matches_unfused_and_oracle(prob, x0, kind, kw):
    relax = Relaxation(kind, **kw)
    assert sim_step.supports_fused(prob, relax)
    fused = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0, fused=True)
    unfused = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0, fused=False)
    oracle = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0, engine="ref")
    _assert_parity(fused, unfused)
    _assert_parity(fused, oracle)


def test_auto_dispatch(prob, x0):
    """auto == fused where supported and d is in the winning regime;
    unsupported (problem, kind) pairs and small d fall back to the unfused
    step instead of erroring."""
    relax = Relaxation("crash_subst", f=3)
    big = Quadratic(dim=128, cond=8.0, sigma=1.0, seed=0)
    auto = simulate(big, relax, P, ALPHA, T, seed=3, fused="auto")
    fused = simulate(big, relax, P, ALPHA, T, seed=3, fused=True)
    np.testing.assert_array_equal(auto.x_final, fused.x_final)

    # below AUTO_MIN_DIM the auto path is the (bit-identical) unfused step
    auto_small = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0,
                          fused="auto")
    unfused_small = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0,
                             fused=False)
    np.testing.assert_array_equal(auto_small.x_final, unfused_small.x_final)

    mlp = MLPClassification(seed=0)
    assert not sim_step.supports_fused(mlp, relax)
    res = simulate(mlp, relax, 4, 0.1, 20, seed=2,
                   x0=np.asarray(mlp.init(seed=1)), fused="auto")
    assert np.isfinite(res.losses).all()
    with pytest.raises(ValueError):
        simulate(mlp, relax, 4, 0.1, 20, seed=2, fused=True)
    with pytest.raises(ValueError):
        simulate(prob, Relaxation("async", tau_max=2), P, ALPHA, T,
                 fused=True)


def test_fused_sweep_matches_single_runs(prob, x0):
    relax = Relaxation("elastic_variance", drop_prob=0.3)
    seeds = [0, 5]
    batch = simulate_sweep(prob, relax, P, ALPHA, T, seeds, x0=x0,
                           fused=True)
    for s, res in zip(seeds, batch):
        single = simulate(prob, relax, P, ALPHA, T, seed=s, x0=x0,
                          fused=True)
        np.testing.assert_allclose(res.x_final, single.x_final,
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# grid level: one compiled program == the Python loop it replaces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [True, False], ids=["fused", "unfused"])
def test_grid_matches_looped_sweep(x0, fused):
    """Multi-problem grid: same-shape (p, d) instances stacked on a batch
    axis reproduce per-problem looped sweeps exactly — with the fused step
    (one program for the whole grid) and with the unfused oracle step."""
    probs = [Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=s) for s in (0, 1)]
    relaxes = [Relaxation("crash_subst", f=3),
               Relaxation("elastic_variance", drop_prob=0.3),
               Relaxation("elastic_variance", drop_prob=0.1)]
    alphas = [0.01, 0.02]
    seeds = [0, 1]
    grid = simulate_grid(probs, relaxes, P, alphas, T, seeds=seeds, x0=x0,
                         fused=fused)
    assert len(grid) == len(probs) * len(relaxes) * len(alphas) * len(seeds)
    for ip, prob_i in enumerate(probs):
        for ir, relax in enumerate(relaxes):
            for ia, alpha in enumerate(alphas):
                swept = simulate_sweep(prob_i, relax, P, alpha, T, seeds,
                                       x0=x0, fused=fused)
                for s, want in zip(seeds, swept):
                    got = grid[(ip, ir, P, ia, s)]
                    np.testing.assert_allclose(
                        got.gap2_over_alpha2, want.gap2_over_alpha2,
                        rtol=1e-4, atol=1e-4)
                    np.testing.assert_allclose(got.losses, want.losses,
                                               rtol=1e-4, atol=1e-5)
                    np.testing.assert_allclose(got.x_final, want.x_final,
                                               rtol=1e-4, atol=1e-5)


def test_grid_matches_looped_sweep_unfused_knobs(x0):
    """A beta sweep over the (unfused) norm-bounded scheduler shares ONE
    compiled program — the float knob is traced, not baked."""
    fresh = Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)
    relaxes = [Relaxation("elastic_norm", beta=b) for b in (0.2, 0.8)]
    grid = simulate_grid(fresh, relaxes, P, ALPHA, T, seeds=(0,), x0=x0)
    for ir, relax in enumerate(relaxes):
        want = simulate(fresh, relax, P, ALPHA, T, seed=0, x0=x0)
        got = grid[(0, ir, P, 0, 0)]
        np.testing.assert_allclose(got.x_final, want.x_final,
                                   rtol=1e-4, atol=1e-5)
    # both betas hit the same cached vmapped program (fresh problem: the
    # cache holds exactly the one grid program this call compiled)
    cache_keys = [k for k in getattr(fresh, "_sim_engine_cache")
                  if k and k[0] == "grid"]
    assert len(cache_keys) == 1


def test_grid_select(prob, x0):
    relaxes = [Relaxation("sync"), Relaxation("crash", f=2)]
    grid = simulate_grid(prob, relaxes, P, ALPHA, T, seeds=(0, 1), x0=x0)
    assert len(grid.select(i_relax=0)) == 2
    assert len(grid.select(seed=1)) == 2
    assert len(grid.select()) == 4
