"""repro.serve invariants: page allocator conservation, windowed-gather
coverage, sampling policies, the bounded-staleness replica, and the
continuous-batching scheduler (deterministic + hypothesis property tests
over a fake engine, mirroring the delivery-ring conservation tests), plus
real-model engine checks (windowed Pallas kernel path, replica-backed
serving, unsupported-arch validation).

The scheduler property tests exploit the actor/step-engine split: the pump
only speaks the `StepEngine` verb surface (``can_admit``/``start``/``step``/
``finish``), so a host-only fake engine with a REAL `PageAllocator` checks
the scheduling invariants without touching jax:

  * every admitted request completes exactly once, with exactly ``max_new``
    tokens,
  * the page pool is fully restored afterwards (no leak, no double-free —
    `PageAllocator.check` would raise),
  * the per-step active batch never exceeds the slot capacity,
  * admission is FIFO (no skip-ahead past a blocked head),
  * the bounded queue rejects overflow instead of growing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models.params import init_params
from repro.serve import (ContinuousScheduler, PagedCacheConfig,
                         PageAllocator, ParamReplica, Request, SampleConfig,
                         StepEngine, sample_tokens, validate_paged_support)
from repro.serve import paged_cache as PC
from repro.serve.sampling import greedy_tokens

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # containers without hypothesis: CI still runs these
    HAVE_HYPOTHESIS = False

FLAGS = TF.RunFlags(remat=False, kv_cache_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

def test_allocator_all_or_nothing():
    pcfg = PagedCacheConfig(page_size=4, num_pages=4, max_requests=2,
                            max_pages_per_seq=4)
    a = PageAllocator(pcfg)
    got = a.alloc("a", 3)
    assert got is not None and len(got) == 3
    assert a.n_free == 1 and not a.can_alloc(2)
    assert a.alloc("b", 2) is None          # refused whole, nothing taken
    assert a.n_free == 1
    a.check()
    assert a.free("a") == 3
    assert a.n_free == 4
    a.check()


def test_allocator_misuse_raises():
    pcfg = PagedCacheConfig(page_size=4, num_pages=4, max_requests=2,
                            max_pages_per_seq=4)
    a = PageAllocator(pcfg)
    a.alloc("a", 1)
    with pytest.raises(ValueError):
        a.alloc("a", 1)                     # rid already holds pages
    with pytest.raises(ValueError):
        a.alloc("b", 0)
    a.free("a")
    with pytest.raises(ValueError):
        a.free("a")                         # double free
    with pytest.raises(ValueError):
        pcfg.pages_needed(17)               # > max_pages_per_seq * page_size


# ---------------------------------------------------------------------------
# windowed gather coverage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps,window,n_table", [
    (4, 6, 8), (8, 16, 4), (8, 7, 2), (32, 96, 4), (16, 16, 1)])
def test_window_slots_cover_live_keys(ps, window, n_table):
    """The static slice [start*ps, (start+n_win)*ps) must contain every live
    key position [max(0, pos-window+1), pos] for every pos in the table."""
    pcfg = PagedCacheConfig(page_size=ps, num_pages=n_table,
                            max_requests=1, max_pages_per_seq=n_table)
    pos = jnp.arange(n_table * ps)
    start, n_win = PC.window_slots(pos, window, pcfg, n_table)
    base = np.asarray(start) * ps
    lo = np.maximum(0, np.asarray(pos) - window + 1)
    assert n_win <= n_table
    assert (base <= lo).all()
    assert (np.asarray(pos) <= base + n_win * ps - 1).all()


def test_gather_all_is_dense_layout():
    """In-order pages reassemble the dense cache exactly (parity path)."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=3, max_requests=1,
                            max_pages_per_seq=3)
    pages = jnp.arange((3 + 1) * 4, dtype=jnp.float32).reshape(4, 4, 1, 1)
    table = jnp.asarray([[0, 1, 2]], jnp.int32)
    out = PC.gather_all(pages, table)
    assert out.shape == (1, 12, 1, 1)
    np.testing.assert_array_equal(np.asarray(out).ravel(), np.arange(12))


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_policies():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (3, 17))
    greedy = sample_tokens(logits, SampleConfig())          # key not needed
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(greedy_tokens(logits)))
    sc = SampleConfig(temperature=0.7, top_k=4)
    k1 = jax.random.PRNGKey(7)
    a = sample_tokens(logits, sc, k1)
    b = sample_tokens(logits, sc, k1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # every draw stays inside the top-k set
    topk = np.argsort(np.asarray(logits), axis=-1)[:, -4:]
    for trial in range(8):
        t = sample_tokens(logits, sc, jax.random.PRNGKey(100 + trial))
        for r, tok in enumerate(np.asarray(t)):
            assert tok in topk[r]
    # top_k=1 is greedy regardless of temperature
    one = sample_tokens(logits, SampleConfig(temperature=2.0, top_k=1),
                        jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(one), np.asarray(greedy))


# ---------------------------------------------------------------------------
# bounded-staleness replica
# ---------------------------------------------------------------------------

def _versioned(v: float):
    return {"w": jnp.full((3,), float(v), jnp.float32)}


@pytest.mark.parametrize("schedule", ["uniform", "straggler", "crash"])
def test_replica_staleness_bound(schedule):
    tau = 3
    rep = ParamReplica(_versioned(0), tau, schedule=schedule, seed=11)
    for v in range(1, 40):
        rep.publish(_versioned(v), v)
        if v % 2 == 0:
            rep.refresh()
        assert 0 <= rep.staleness <= tau
        served = rep.serving_params()
        # the served snapshot is exactly the serving_version's params
        assert float(served["w"][0]) == float(rep.serving_version)
    assert rep.latest_version == 39


def test_replica_tau_zero_always_latest():
    rep = ParamReplica(_versioned(0), 0)
    for v in range(1, 10):
        rep.publish(_versioned(v))
        assert rep.staleness == 0
        assert float(rep.serving_params()["w"][0]) == float(v)


def test_replica_publish_must_advance_by_one():
    rep = ParamReplica(_versioned(0), 2)
    rep.publish(_versioned(1), 1)
    with pytest.raises(ValueError):
        rep.publish(_versioned(5), 5)
    with pytest.raises(ValueError):
        ParamReplica(_versioned(0), -1)


# ---------------------------------------------------------------------------
# scheduler over a fake engine (host-only, real allocator)
# ---------------------------------------------------------------------------

class FakeEngine:
    """StepEngine verb surface without a model: tokens are synthetic, pages
    come from a real `PageAllocator` so conservation bugs surface."""

    def __init__(self, pcfg: PagedCacheConfig):
        self.pcfg = pcfg
        self.alloc = PageAllocator(pcfg)
        self.active = np.zeros(pcfg.max_requests, bool)
        self._slot_of: dict = {}
        self.steps = 0
        self.max_active = 0

    def has_slot(self) -> bool:
        return int(self.active.sum()) < self.pcfg.max_requests

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        total = prompt_len + max_new
        if total > self.pcfg.max_pages_per_seq * self.pcfg.page_size:
            raise ValueError("request exceeds per-request capacity")
        return self.has_slot() and self.alloc.can_alloc(
            self.pcfg.pages_needed(total))

    def start(self, rid, prompt, max_new):
        pages = self.alloc.alloc(rid, self.pcfg.pages_needed(
            len(prompt) + max_new))
        assert pages is not None
        slot = int(np.flatnonzero(~self.active)[0])
        self.active[slot] = True
        self._slot_of[rid] = slot
        self.max_active = max(self.max_active, int(self.active.sum()))
        return np.asarray([9000 + rid], np.int32)

    def step(self):
        self.steps += 1
        self.max_active = max(self.max_active, int(self.active.sum()))
        return np.arange(self.pcfg.max_requests, dtype=np.int32) * 1000 \
            + self.steps

    def finish(self, rid) -> None:
        slot = self._slot_of.pop(rid)
        self.alloc.free(rid)
        self.active[slot] = False

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]


def _check_run(engine: FakeEngine, sched: ContinuousScheduler, toks: dict,
               reqs: list):
    admitted = [r for r in reqs if r.rid in sched.completions]
    assert len(toks) == len(admitted)
    for req in admitted:
        comp = sched.completions[req.rid]
        assert comp.tokens is not None and len(comp.tokens) == req.max_new
        assert comp.tokens[0] == 9000 + req.rid       # the prefill token
        assert req.arrival <= comp.admitted <= comp.finished
    engine.alloc.check()
    assert engine.alloc.n_free == engine.pcfg.num_pages
    assert engine.max_active <= engine.pcfg.max_requests
    assert not sched._live and not sched.queue


def test_scheduler_mixed_trace_deterministic():
    pcfg = PagedCacheConfig(page_size=4, num_pages=6, max_requests=2,
                            max_pages_per_seq=3)
    engine = FakeEngine(pcfg)
    sched = ContinuousScheduler(engine)
    reqs = [Request(rid=i, prompt=np.zeros(p, np.int32), max_new=g,
                    arrival=a)
            for i, (p, g, a) in enumerate(
                [(4, 3, 0), (8, 4, 0), (2, 1, 1), (5, 6, 2), (1, 2, 9)])]
    toks = sched.run(reqs)
    _check_run(engine, sched, toks, reqs)
    assert sched.rejected == 0
    p50, p99 = sched.latency_percentiles()
    assert 0 < p50 <= p99


def test_scheduler_fifo_no_skip_ahead():
    """A small request must not jump past a blocked queue head."""
    pcfg = PagedCacheConfig(page_size=4, num_pages=2, max_requests=2,
                            max_pages_per_seq=2)
    engine = FakeEngine(pcfg)
    sched = ContinuousScheduler(engine)
    reqs = [Request(rid=0, prompt=np.zeros(1, np.int32), max_new=3),
            Request(rid=1, prompt=np.zeros(4, np.int32), max_new=4),
            Request(rid=2, prompt=np.zeros(1, np.int32), max_new=1)]
    for req in reqs:
        sched.submit(req)
    sched.step()
    # rid 0 holds 1 page; head rid 1 needs 2 (blocked); rid 2 would fit but
    # must wait behind the head
    assert 0 in sched._live and 1 not in sched._live and 2 not in sched._live
    while sched.queue or sched._live:
        sched.step()
    toks = sched.drain()
    _check_run(engine, sched, toks, reqs)
    assert sorted(toks) == [0, 1, 2]
    # FIFO: rid 1 admitted no later than rid 2
    assert sched.completions[1].admitted <= sched.completions[2].admitted


def test_scheduler_bounded_queue_rejects():
    pcfg = PagedCacheConfig(page_size=4, num_pages=1, max_requests=1,
                            max_pages_per_seq=1)
    sched = ContinuousScheduler(FakeEngine(pcfg), queue_limit=2)
    accepted = [sched.submit(Request(rid=i, prompt=np.zeros(1, np.int32),
                                     max_new=1)) for i in range(5)]
    assert accepted == [True, True, False, False, False]
    assert sched.rejected == 3


def test_scheduler_drain_single_host_fetch(monkeypatch):
    """The whole run — every decode step plus drain — performs exactly ONE
    device->host fetch.  Pins the coalesced ``jax.device_get((stacked,
    first_toks))`` in `ContinuousScheduler.drain` against regressing back
    to per-request ``np.asarray`` pulls (one blocking sync each, flagged
    by `repro.analysis`'s transfer detector)."""
    calls = []
    real_get = jax.device_get

    def counting_get(x):
        calls.append(x)
        return real_get(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    pcfg = PagedCacheConfig(page_size=4, num_pages=6, max_requests=2,
                            max_pages_per_seq=3)
    engine = FakeEngine(pcfg)
    sched = ContinuousScheduler(engine)
    reqs = [Request(rid=i, prompt=np.zeros(p, np.int32), max_new=g,
                    arrival=a)
            for i, (p, g, a) in enumerate(
                [(4, 3, 0), (8, 4, 0), (2, 1, 1), (5, 6, 2)])]
    toks = sched.run(reqs)
    _check_run(engine, sched, toks, reqs)
    assert len(calls) == 1, (
        f"expected one coalesced drain fetch, saw {len(calls)} device_get "
        f"calls across the run")
    stacked, firsts = calls[0]          # the one fetch carries everything
    assert stacked.shape[0] == engine.steps
    assert sorted(firsts) == sorted(r.rid for r in reqs)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_scheduler_properties(data):
        ps = data.draw(st.sampled_from([4, 8]), label="page_size")
        max_pages = data.draw(st.integers(1, 4), label="max_pages_per_seq")
        slots = data.draw(st.integers(1, 3), label="slots")
        num_pages = data.draw(st.integers(max_pages, 3 * max_pages),
                              label="num_pages")
        cap = max_pages * ps
        n = data.draw(st.integers(1, 10), label="n_requests")
        reqs = []
        for i in range(n):
            g = data.draw(st.integers(1, cap - 1), label=f"gen{i}")
            p = data.draw(st.integers(1, cap - g), label=f"prompt{i}")
            a = data.draw(st.integers(0, 15), label=f"arrival{i}")
            reqs.append(Request(rid=i, prompt=np.zeros(p, np.int32),
                                max_new=g, arrival=a))
        pcfg = PagedCacheConfig(page_size=ps, num_pages=num_pages,
                                max_requests=slots,
                                max_pages_per_seq=max_pages)
        engine = FakeEngine(pcfg)
        sched = ContinuousScheduler(engine, queue_limit=64)
        toks = sched.run(reqs, max_steps=5000)
        # every request fits per-request capacity, so all must complete
        assert len(toks) == n
        _check_run(engine, sched, toks, reqs)


# ---------------------------------------------------------------------------
# real-model engine paths
# ---------------------------------------------------------------------------

def test_validate_paged_support():
    assert validate_paged_support(get_config("qwen3-1.7b").reduced()) == 0
    with pytest.raises(NotImplementedError):
        validate_paged_support(get_config("zamba2-7b").reduced())  # SSM
    gemma = dataclasses.replace(get_config("gemma3-27b").reduced(),
                                n_layers=5, global_every=2)
    with pytest.raises(NotImplementedError):
        validate_paged_support(gemma)       # non-uniform local:global mix


def _run_engine(engine, prompts, gens):
    sched = ContinuousScheduler(engine)
    trace = [Request(rid=i, prompt=p, max_new=g, arrival=0)
             for i, (p, g) in enumerate(zip(prompts, gens))]
    return sched.run(trace)


def test_windowed_engine_kernel_matches_oracle():
    """ps=32 / window=96 makes the windowed gather exactly 128 keys wide, so
    ``use_kernel=True`` genuinely runs the Pallas swa kernel (interpret
    mode) on the paged decode hot path; it must agree with the masked-chunk
    oracle token for token."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              sliding_window=96)
    assert validate_paged_support(cfg) == 96
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(2))
    pcfg = PagedCacheConfig(page_size=32, num_pages=8, max_requests=2,
                            max_pages_per_seq=4)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=32, dtype=np.int32)
               for _ in range(2)]
    gens = [12, 20]
    out = {}
    for use_kernel in (False, True):
        engine = StepEngine(cfg, params, pcfg, FLAGS, use_kernel=use_kernel)
        out[use_kernel] = _run_engine(engine, prompts, gens)
        engine.alloc.check()
    for rid in range(2):
        np.testing.assert_array_equal(out[True][rid], out[False][rid])


def test_replica_backed_engine_serves_within_bound():
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(3))
    tau = 2
    replica = ParamReplica(params, tau, schedule="uniform", seed=1)
    pcfg = PagedCacheConfig(page_size=8, num_pages=4, max_requests=1,
                            max_pages_per_seq=2)
    engine = StepEngine(cfg, params, pcfg, FLAGS, replica=replica)
    sched = ContinuousScheduler(engine)
    rng = np.random.default_rng(6)
    sched.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, size=8,
                                   dtype=np.int32), max_new=8))
    v = 0
    while sched.queue or sched._live:
        v += 1
        replica.publish(params, v)          # trainer advances every step
        if v % 2 == 0:
            replica.refresh()
        sched.step()
        assert 0 <= replica.staleness <= tau
        assert v < 100
    toks = sched.drain()
    assert len(toks[0]) == 8
    engine.alloc.check()
