"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over shapes
and dtypes per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.onebit_ef import onebit_ef, onebit_ef_ref, unpack
from repro.kernels.swa_attention import swa_decode_attention, swa_decode_ref
from repro.kernels.topk_ef import topk_ef, topk_ef_ref
from repro.kernels.topk_ef.ops import decompress_sum


@pytest.mark.parametrize("m,r,k", [(8, 64, 4), (16, 256, 8), (32, 128, 1),
                                   (8, 1024, 32), (64, 96, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_ef_matches_ref(m, r, k, dtype):
    key = jax.random.PRNGKey(m * r + k)
    g = jax.random.normal(key, (m, r), dtype)
    e = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (m, r),
                                jnp.float32)
    v1, i1, e1 = topk_ef(g, e, k=k, interpret=True)
    v2, i2, e2 = topk_ef_ref(g, e, k=k)
    # selection sets must match (order can differ on ties)
    np.testing.assert_allclose(np.sort(np.abs(v1), 1), np.sort(np.abs(v2), 1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(e1, e2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,r", [(8, 64), (16, 256), (8, 1024), (24, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_onebit_ef_matches_ref(m, r, dtype):
    key = jax.random.PRNGKey(m + r)
    g = jax.random.normal(key, (m, r), dtype)
    e = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (m, r),
                                jnp.float32)
    p1, m1, e1 = onebit_ef(g, e, interpret=True)
    p2, m2, e2 = onebit_ef_ref(g, e)
    assert bool(jnp.all(p1 == p2))
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e1, e2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,t,kv,g,d", [
    (1, 256, 1, 4, 64), (2, 1024, 2, 2, 128), (1, 512, 4, 1, 64),
])
@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_matches_ref(b, t, kv, g, d, window, dtype):
    key = jax.random.PRNGKey(b * t + d + window)
    q = jax.random.normal(key, (b, kv, g, d), dtype)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d), dtype)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d), dtype)
    for pos in (3, t // 2, t - 1):
        out = swa_decode_attention(q, kc, vc, jnp.int32(pos), window=window,
                                   block_t=128, interpret=True)
        ref = swa_decode_ref(q, kc, vc, pos, window=window)
        atol = 3e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=atol)


def test_topk_wire_roundtrip_sums():
    """decompress_sum over P workers' payloads equals sum of dense Q(w)."""
    key = jax.random.PRNGKey(0)
    p, m, r, k = 4, 8, 128, 8
    dense_sum = jnp.zeros((m, r))
    vals, idxs = [], []
    for i in range(p):
        g = jax.random.normal(jax.random.fold_in(key, i), (m, r))
        e = jnp.zeros((m, r))
        v, ix, e2 = topk_ef(g, e, k=k, interpret=True)
        vals.append(v)
        idxs.append(ix)
        dense_sum = dense_sum + (g - e2)  # Q(w) = w - err
    got = decompress_sum(jnp.stack(vals), jnp.stack(idxs), r)
    np.testing.assert_allclose(got, dense_sum, rtol=1e-5, atol=1e-5)


def test_topk_blocklocal_contraction():
    """Kernel's row-local selection still satisfies Eq. 25 with the row
    ratio's gamma (the property Lemma 18 needs)."""
    key = jax.random.PRNGKey(3)
    m, r, k = 16, 256, 16
    w = jax.random.normal(key, (m, r))
    v, ix, err = topk_ef(w, jnp.zeros((m, r)), k=k, interpret=True)
    q = w - err
    gamma = (r - k) / r
    assert float(jnp.sum((q - w) ** 2)) <= gamma * float(jnp.sum(w ** 2))


@pytest.mark.parametrize("b,t,h,hd,n,chunk", [
    (1, 256, 2, 64, 32, 128), (2, 128, 4, 32, 64, 64), (1, 512, 1, 128, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_ref(b, t, h, hd, n, chunk, dtype):
    from repro.kernels.ssd import ssd_chunked_kernel, ssd_ref
    key = jax.random.PRNGKey(b * t + h)
    xh = jax.random.normal(key, (b, t, h, hd), dtype)
    a = -0.1 * jax.random.uniform(jax.random.fold_in(key, 1), (b, t, h))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, t, n), dtype)
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, t, n), dtype)
    y1, s1 = ssd_chunked_kernel(xh, a, bm, cm, chunk=chunk, interpret=True)
    y2, s2 = ssd_ref(xh, a, bm, cm)
    atol = 2e-4 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=atol,
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=atol,
                               rtol=1e-2)
