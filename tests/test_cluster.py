"""Tests for `repro.cluster` — the discrete-event cluster model and the
time-to-loss co-simulation — plus the tau-table plumbing it adds to
`core.delivery` and the roofline bench's analytic fallback.

The load-bearing property: the tau tables the event loop *measures* must
satisfy exactly the invariants `core.delivery`'s rings pin — every live
message delivered exactly once within ``tau_max``, DROPPED rows never
delivered.  That is checked by driving `test_delivery.check_ring_invariants`
with measured tables, not synthetic ones.
"""
import json
import os

import numpy as np
import pytest

from repro.cluster import (ClusterSpec, TraceEvent, analytic_record, preset,
                           rank_candidates, simulate_cluster, trace_tables,
                           winners)
from repro.cluster.cosim import Candidate
from repro.core.delivery import (DROPPED, taus_to_message_delays,
                                 validate_tau_table)

from test_delivery import check_ring_invariants


# ---------------------------------------------------------------------------
# ClusterSpec: validation, serialization, generation
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    spec = preset("straggler_heavy", p=4, steps=120)
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert again.events == spec.events       # TraceEvents revive typed


def test_spec_save_load_file(tmp_path):
    spec = preset("preemptible", p=3, steps=60)
    path = spec.save(str(tmp_path / "spec.json"))
    assert ClusterSpec.load(path) == spec
    # inline JSON is accepted too (FaultPlan idiom)
    assert ClusterSpec.load(spec.to_json()) == spec


def test_spec_random_deterministic():
    a = ClusterSpec.random(seed=7, p=4, steps=100)
    b = ClusterSpec.random(seed=7, p=4, steps=100)
    assert a == b
    assert ClusterSpec.random(seed=8, p=4, steps=100) != a
    assert all(e.kind in ("straggle", "preempt", "netdeg")
               for e in a.events)


def test_spec_validation():
    with pytest.raises(ValueError):
        TraceEvent(step=0, kind="meteor", worker=0)
    with pytest.raises(ValueError):
        TraceEvent(step=-1, kind="straggle", worker=0)
    with pytest.raises(ValueError):
        ClusterSpec(p=0)
    with pytest.raises(ValueError):
        ClusterSpec(p=4, flops_per_s=(1e9, 2e9))   # neither 1 nor p
    with pytest.raises(ValueError):
        preset("nope")


def test_spec_rate_broadcast():
    spec = ClusterSpec(p=3, flops_per_s=(1e9,),
                       link_bytes_per_s=(1e8, 2e8, 3e8))
    np.testing.assert_array_equal(spec.rates, [1e9] * 3)
    np.testing.assert_array_equal(spec.bandwidth, [1e8, 2e8, 3e8])


def test_trace_tables_apply_events():
    spec = ClusterSpec(p=2, flops_per_s=(1e9,), link_bytes_per_s=(1e8,),
                       events=(
                           TraceEvent(step=2, kind="straggle", worker=0,
                                      duration=3, factor=4.0),
                           TraceEvent(step=1, kind="netdeg", worker=1,
                                      duration=0, factor=2.0),
                           TraceEvent(step=4, kind="preempt", worker=1,
                                      duration=2),
                       ))
    rates, bw, alive = trace_tables(spec, 8)
    np.testing.assert_allclose(rates[2:5, 0], 2.5e8)   # straggle window
    np.testing.assert_allclose(rates[5:, 0], 1e9)      # ... then recovers
    np.testing.assert_allclose(bw[1:, 1], 5e7)         # netdeg to run end
    assert not alive[4:6, 1].any() and alive[6:, 1].all()


# ---------------------------------------------------------------------------
# event loop: staleness invariants on MEASURED tables
# ---------------------------------------------------------------------------

def test_event_loop_sync_is_bsp():
    """tau_max=0 degenerates to bulk-synchronous: zero staleness and the
    learner clock paced by the slowest worker."""
    spec = preset("uniform", p=4, steps=40)
    run = simulate_cluster(spec, 40, 0, 4e8, 4.7e6)
    assert (run.taus == 0).all()
    assert (np.diff(run.closes) > 0).all()


def test_event_loop_free_running_saturates_tau():
    """On a uniform cluster with tau_max=4, free-running workers sit at the
    staleness bound in steady state (they are never the gate)."""
    run = simulate_cluster(preset("uniform", p=4, steps=60), 60, 4,
                           4e8, 4.7e6)
    assert run.taus.max() == 4
    assert (np.diff(run.closes) > 0).all()


@pytest.mark.parametrize("shape", ["uniform", "straggler_heavy",
                                   "preemptible"])
@pytest.mark.parametrize("tau_max", [0, 2, 4])
def test_measured_taus_within_bound(shape, tau_max):
    run = simulate_cluster(preset(shape, p=4, steps=50), 50, tau_max,
                           4e8, 5e5)
    validate_tau_table(run.taus, tau_max)       # raises on violation
    live = run.taus[run.taus != DROPPED]
    assert live.min() >= 0 and live.max() <= tau_max


def test_measured_taus_satisfy_ring_exactly_once():
    """THE acceptance property: tables measured off the event loop drive
    `core.delivery`'s rings with exactly-once delivery — including across
    preemption windows (DROPPED rows lose exactly their own messages)."""
    for shape, tau_max in (("straggler_heavy", 3), ("preemptible", 4),
                           ("uniform", 2)):
        run = simulate_cluster(preset(shape, p=4, steps=40), 40, tau_max,
                               4e8, 5e5)
        check_ring_invariants(run.taus, tau_max)


def test_preemption_emits_dropped_rows():
    run = simulate_cluster(preset("preemptible", p=4, steps=80), 80, 4,
                           4e8, 4.7e6)
    assert (run.taus == DROPPED).any()
    dead = run.taus == DROPPED
    # DROPPED only where the trace preempted, and histogram keys are legal
    _, _, alive = trace_tables(run.spec, 80)
    np.testing.assert_array_equal(dead, ~alive)
    assert set(run.tau_histogram()) <= set(range(-1, 5))


def test_straggler_cluster_prices_wire():
    """The congested worker makes the dense sync wire slower than the
    compressed one on straggler_heavy — the rate-ratio effect the co-sim
    trades on (dense 4.7MB vs top-k 55kB per step)."""
    spec = preset("straggler_heavy", p=4, steps=60)
    dense = simulate_cluster(spec, 60, 0, 4e8, 4.7e6)
    sparse = simulate_cluster(spec, 60, 0, 4e8, 5.5e4)
    assert sparse.total_s < 0.5 * dense.total_s


# ---------------------------------------------------------------------------
# delivery plumbing: validate_tau_table / taus_to_message_delays
# ---------------------------------------------------------------------------

def test_validate_tau_table_rejects_bad_tables():
    good = np.zeros((4, 2), np.int32)
    assert validate_tau_table(good, 1).dtype == np.int32
    with pytest.raises(ValueError):
        validate_tau_table(np.full((4, 2), 3, np.int32), 2)   # > tau_max
    with pytest.raises(ValueError):
        validate_tau_table(np.full((4, 2), -2, np.int32), 2)  # < DROPPED
    with pytest.raises(ValueError):
        validate_tau_table(np.zeros((4, 2), np.float32), 2)   # not integer
    with pytest.raises(ValueError):
        validate_tau_table(np.zeros((4,), np.int32), 2)       # not (T, p)


def test_taus_to_message_delays_broadcast():
    taus = np.array([[0, 2], [DROPPED, 1]], np.int32)
    delays = taus_to_message_delays(taus)
    assert delays.shape == (2, 2, 2)
    # layout is delays[t, receiver, sender]: sender w's delay reaches
    # every *other* receiver; a worker's own gradient is immediate
    assert delays[0, 0, 1] == 2 and delays[0, 1, 0] == 0
    assert delays[0, 0, 0] == 0 and delays[0, 1, 1] == 0
    assert delays[1, 1, 0] == DROPPED        # dropped stays dropped
    assert delays[1, 0, 1] == 1


# ---------------------------------------------------------------------------
# co-simulation
# ---------------------------------------------------------------------------

CANDS = (Candidate("sync", "sync", "sync", 0),
         Candidate("async_tau3", "async_tau4", "async", 3))


def test_rank_candidates_sane_and_deterministic():
    """Every candidate reaches the (loose) target, time-to-loss reads off
    the candidate's own clock, and a re-run reproduces the ranking bit for
    bit (seeded schedules, measured traces — no hidden randomness)."""
    spec = preset("uniform", p=4, steps=120)
    results, runs = rank_candidates(spec, CANDS, t_len=120,
                                    target_frac=0.05)
    assert {r.candidate for r in results} == {"sync", "async_tau3"}
    for r in results:
        assert np.isfinite(r.steps_to_loss) and np.isfinite(r.time_to_loss)
        assert r.time_to_loss <= runs[r.candidate].total_s + 1e-9
    win = winners(results)
    assert win["steps"] in ("sync", "async_tau3")
    again, _ = rank_candidates(spec, CANDS, t_len=120, target_frac=0.05)
    assert again == results


def test_rank_candidates_replays_measured_trace():
    """The async convergence run consumes the cluster's measured tau table
    (not a random draw): the emitted delays keep ring invariants."""
    spec = preset("straggler_heavy", p=4, steps=100)
    _, runs = rank_candidates(spec, CANDS, t_len=100, target_frac=0.05)
    taus = runs["async_tau3"].taus
    validate_tau_table(taus, 3)
    check_ring_invariants(taus, 3)


def test_cosim_cli_writes_ranking(monkeypatch, tmp_path, capsys):
    from repro.launch import cosim as cli

    spec_path = preset("straggler_heavy", p=4, steps=80).save(
        str(tmp_path / "spec.json"))
    out = tmp_path / "ranking.json"
    monkeypatch.setattr("sys.argv", [
        "cosim", "--cluster", spec_path, "--steps", "80",
        "--target-frac", "0.05", "--out", str(out)])
    assert cli.main() == 0
    text = capsys.readouterr().out
    assert "winner by  time-to-loss" in text
    data = json.loads(out.read_text())
    assert data["winners"]["time"] in {c["name"] for c in data["candidates"]}
    assert data["cluster"] == json.loads(
        ClusterSpec.load(spec_path).to_json())


def test_cosim_cli_rejects_unknown_cluster():
    from repro.launch import cosim as cli
    with pytest.raises(SystemExit):
        cli.load_cluster("not-a-preset-or-file", 4, 100)


def test_winners_all_unreached():
    results, _ = rank_candidates(preset("uniform", p=4, steps=8),
                                 CANDS[:1], t_len=8, target_frac=1e-12)
    assert winners(results) == {"steps": None, "time": None}


# ---------------------------------------------------------------------------
# roofline analytic fallback (the bench that never produced a row)
# ---------------------------------------------------------------------------

def test_analytic_record_shape():
    rec = analytic_record("qwen3-1.7b-smoke", "train_4k")
    assert rec["status"] == "ok"
    assert rec["costs"]["flops"] > 0 and rec["costs"]["bytes"] > 0
    assert rec["costs"]["collectives"]["total"] > 0      # train all-reduces
    dec = analytic_record("qwen3-1.7b-smoke", "decode_32k")
    assert dec["costs"]["collectives"]["total"] == 0     # decode does not


def test_bench_roofline_emits_rows_without_artifacts(monkeypatch, tmp_path):
    """With no dryrun artifacts and the smoke flag set (CI fast lane), the
    bench emits REAL rows from the analytic model — the placeholder row is
    gone."""
    import benchmarks.bench_roofline as BR
    monkeypatch.setattr(BR, "DRYRUN_DIR", str(tmp_path / "none"))
    monkeypatch.setattr(BR, "SMOKE", True)
    monkeypatch.chdir(tmp_path)                 # roofline.md lands here
    rows = BR.run()
    names = [r[0] for r in rows]
    assert names and all(n.startswith("roofline/") for n in names)
    assert not any("no_dryrun_artifacts" in n for n in names)
    assert all("src=model" in r[2] for r in rows)
    assert os.path.exists(tmp_path / "experiments" / "roofline.md")


def test_bench_roofline_skips_torn_artifact(monkeypatch, tmp_path):
    """A dry-run killed mid-write leaves a torn JSON: the loader warns and
    skips it instead of sinking the whole bench."""
    import benchmarks.bench_roofline as BR
    d = tmp_path / "dryrun"
    d.mkdir()
    (d / "a__x__single__exact.json").write_text('{"arch": "torn", ')
    good = analytic_record("qwen3-1.7b-smoke", "train_4k")
    (d / "b__y__single__exact.json").write_text(json.dumps(good))
    monkeypatch.setattr(BR, "DRYRUN_DIR", str(d))
    with pytest.warns(UserWarning, match="unreadable dryrun artifact"):
        rows = BR.load_all()
    assert len(rows) == 1 and rows[0]["arch"] == "qwen3-1.7b-smoke"
