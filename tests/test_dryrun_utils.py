"""Dry-run harness unit tests: HLO collective parser, seq fitting, depth
selection, skip gating, and input_specs shapes (no 512-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist modules not seeded in this snapshot")

# dryrun sets XLA_FLAGS at import; importing in-process is fine because this
# test session never builds the 512-device mesh (flag only affects first
# backend init — tests here are pure python).
from repro.launch import dryrun as DR  # noqa: E402
from repro.configs import INPUT_SHAPES, get_config  # noqa: E402


HLO = """
ENTRY %main {
  %ar = f32[16,4096,2048]{2,1,0} all-reduce(f32[16,4096,2048] %x), replica_groups={}
  %ag.1 = bf16[32,128]{1,0} all-gather(bf16[2,128] %y), dimensions={0}
  %tup = (f32[8,8]{1,0}, f32[8]{0}) all-reduce(f32[8,8] %a, f32[8] %b)
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4] %z)
  %ars = f32[2,2]{1,0} all-reduce-start(f32[2,2] %w)
  %fusion.1 = f32[4]{0} fusion(%all-gather.55, %c), kind=kLoop
  %gte = f32[9,9]{1,0} get-tuple-element(%all-reduce.548), index=1
}
"""


def test_collective_parser_counts_and_weights():
    out = DR.collective_bytes(HLO)
    assert out["all-reduce"] == 2 * (16 * 4096 * 2048 * 4) \
        + 2 * (8 * 8 * 4 + 8 * 4) + 2 * (2 * 2 * 4)
    assert out["all-gather"] == 32 * 128 * 2
    assert out["collective-permute"] == 4 * 4 * 4
    # operand mentions (fusion, get-tuple-element) must NOT count
    total = sum(v for k, v in out.items() if k != "total")
    assert out["total"] == total


def test_fit_seq_linear_and_quadratic():
    lin = {1024: 10.0, 2048: 20.0, 4096: 40.0}
    assert abs(DR._fit_seq(lin, 32768) - 320.0) < 1e-6
    quad = {s: 2.0 * s * s for s in (1024, 2048, 4096)}
    assert abs(DR._fit_seq(quad, 8192) - 2.0 * 8192 ** 2) < 1.0


def test_reduced_depths_zero_base():
    assert DR.reduced_depths(get_config("qwen3-1.7b")) == (0, 1)
    assert DR.reduced_depths(get_config("gemma3-27b")) == (0, 6)
    assert DR.reduced_depths(get_config("zamba2-7b")) == (0, 6)


def test_should_skip_long500k_gating():
    long = INPUT_SHAPES["long_500k"]
    assert DR.should_skip(get_config("mistral-nemo-12b"), long) is not None
    assert DR.should_skip(get_config("qwen3-1.7b"), long) is not None
    for a in ("rwkv6-1.6b", "zamba2-7b", "mixtral-8x7b", "gemma3-27b"):
        assert DR.should_skip(get_config(a), long) is None
    assert DR.should_skip(get_config("qwen3-1.7b"),
                          INPUT_SHAPES["train_4k"]) is None


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "train_4k"), ("internvl2-2b", "prefill_32k"),
    ("musicgen-large", "train_4k"), ("rwkv6-1.6b", "decode_32k"),
])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    s = INPUT_SHAPES[shape]
    from repro.models.transformer import RunFlags
    specs = DR.input_specs(cfg, s, RunFlags(remat=False))
    b = specs["batch"]
    if s.kind == "decode":
        assert b["tokens"].shape == (s.global_batch, 1)
        assert "cache" in specs
        assert len(jax.tree.leaves(specs["cache"])) > 1  # pos + state/kv
    else:
        assert b["tokens"].shape == (s.global_batch, s.seq_len)
    if cfg.frontend == "vision" and s.kind != "decode":
        assert b["patch_embeds"].shape == (
            s.global_batch, cfg.n_prefix_embeds, cfg.d_model)
    if cfg.frontend == "audio" and s.kind != "decode":
        assert b["frame_embeds"].shape == (
            s.global_batch, s.seq_len, cfg.d_model)
