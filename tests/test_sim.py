"""Exact-semantics simulator vs the paper's own claims:

  * every relaxation's measured elastic constant B_hat respects the Table-1
    bound computed from the same (M, sigma, p, f, tau, gamma),
  * convergence holds under every relaxation (Theorems 2/4 empirically),
  * the adversarial oracle slows down linearly in B^2 (Lemma 6 direction).
"""
import numpy as np
import pytest

from repro.core import compression as C, theory
from repro.core.problems import MLPClassification, Quadratic
from repro.core.sim import Relaxation, simulate, simulate_shared_memory

P, T, ALPHA = 8, 500, 0.02
DIM = 32


@pytest.fixture(scope="module")
def prob():
    return Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)


@pytest.fixture(scope="module")
def x0():
    return np.ones(DIM, np.float32) * 2.0


def _m2(prob, x0):
    r2 = float(np.sum((x0 - np.asarray(prob.x_star)) ** 2)) * 1.5
    return prob.m2_estimate(r2)


CASES = [
    ("crash", dict(f=3), lambda p, m2, s2: theory.b_crash_m(P, 3, m2)),
    ("crash_subst", dict(f=3),
     lambda p, m2, s2: theory.b_crash_variance(P, 3, s2)),
    ("omission", dict(f=6, drop_prob=0.2),
     lambda p, m2, s2: theory.b_crash_m(P, 6, m2)),
    ("async", dict(tau_max=2),
     lambda p, m2, s2: theory.b_async_mp(P, 2, m2)),
    ("elastic_variance", dict(drop_prob=0.3),
     lambda p, m2, s2: theory.b_elastic_scheduler_variance(s2)),
]


@pytest.mark.parametrize("kind,kw,bound", CASES,
                         ids=[c[0] for c in CASES])
def test_b_hat_within_table1_bound(prob, x0, kind, kw, bound):
    res = simulate(prob, Relaxation(kind, **kw), P, ALPHA, T, seed=3, x0=x0)
    b_theory = bound(prob, _m2(prob, x0), prob.sigma2)
    assert res.b_hat <= b_theory * 1.05, (kind, res.b_hat, b_theory)
    # and convergence was not destroyed
    assert res.losses[-1] < 0.05 * res.losses[0]


@pytest.mark.parametrize("comp,gamma_fn", [
    (C.topk_compressor(0.25), lambda n: C.topk_gamma(n, n // 4)),
    (C.onebit_compressor(), C.onebit_gamma),
], ids=["topk", "onebit"])
def test_ef_compression_bound(prob, x0, comp, gamma_fn):
    res = simulate(prob, Relaxation("ef_comp", compressor=comp),
                   P, ALPHA, T, seed=3, x0=x0)
    b = theory.b_ef_compression(gamma_fn(DIM), _m2(prob, x0))
    assert res.b_hat <= b * 1.05
    assert res.losses[-1] < 0.05 * res.losses[0]


def test_shared_memory_bound(prob, x0):
    res = simulate_shared_memory(prob, P, 0.005, T, tau_max=3, seed=3, x0=x0)
    b = theory.b_shared_memory(DIM, 3, _m2(prob, x0))
    assert res.b_hat <= b
    assert res.losses[-1] < 0.5 * res.losses[0]


def test_strongly_convex_rate_vs_thm5(prob, x0):
    """Measured E||x_T - x*||^2 under the paper's alpha must respect the
    Theorem 5 RHS (sync case: B = 0)."""
    import math
    Tl = 800
    alpha = 2 * (math.log(Tl) + math.log(P)) / (prob.c * Tl)
    res = simulate(prob, Relaxation("sync"), P, alpha, Tl, seed=5, x0=x0)
    pc = prob.constants(x0)
    rhs = theory.thm5_rhs(pc, 0.0, Tl, P)
    dist2 = float(np.sum((res.x_final - np.asarray(prob.x_star)) ** 2))
    assert dist2 <= rhs, (dist2, rhs)


def test_lemma6_slowdown_monotone_in_b(prob, x0):
    """Adversarial oracle: larger B => worse final distance (Lemma 6)."""
    finals = []
    for b in (0.0, 20.0, 80.0):
        res = simulate(prob, Relaxation("adversarial", B_adv=b), P, ALPHA,
                       400, seed=7, x0=x0)
        finals.append(float(np.sum(
            (res.x_final - np.asarray(prob.x_star)) ** 2)))
    assert finals[0] < finals[1] < finals[2], finals


def test_nonconvex_convergence_under_relaxations():
    """MLP: every relaxation reaches a small gradient norm (Theorem 2/3
    qualitatively) and beats a no-training baseline on loss."""
    mlp = MLPClassification(seed=0)
    x0 = mlp.init(seed=1)
    base = float(mlp.loss(x0))
    for kind, kw in [("sync", {}), ("elastic_variance", dict(drop_prob=0.3)),
                     ("async", dict(tau_max=2))]:
        res = simulate(mlp, Relaxation(kind, **kw), 4, 0.1, 400, seed=2,
                       x0=np.asarray(x0))
        assert res.losses[-1] < 0.7 * base, (kind, res.losses[-1], base)
        assert res.grad_norms2[-1] < res.grad_norms2[0]
