"""Parity of the shard_map training paths against the GSPMD baseline.

`make_train_step` (single-program data parallelism, GSPMD collectives) is
the reference semantics; these tests pin the two manual-collective paths
against it over a 10-step training run:

  * `make_elastic_train_step` with the ``exact`` strategy — the shard_map
    body + hand-written pmean must be the same math,
  * `make_async_train_step` with ``tau_max=0`` — a capacity-1 delay ring is
    deposit-then-take of the same slot, i.e. synchronous SGD.

The async engine's staleness semantics (tau bound honored, EF residuals
live only when configured) are covered here too, so the whole engine
surface is exercised without a multi-device mesh (test_system and
bench_async_ef cover real cross-shard traffic in subprocesses).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import SyncConfig
from repro.data.pipeline import SyntheticLMDataset
from repro.dist import sharding as SH
from repro.dist.async_engine import (AsyncConfig, init_async_state,
                                     make_async_train_step)
from repro.dist.train import (init_dist_sync_state, make_elastic_train_step,
                              make_train_step)
from repro.jax_compat import make_mesh
from repro.models import transformer as TF
from repro.models.params import init_params, param_specs

N_STEPS = 10
TOL = 1e-5


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b").reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params = init_params(defs, jax.random.PRNGKey(0))
    opt = momentum(1e-2, 0.9)
    data = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=0)
    batches = [data.batch(t) for t in range(N_STEPS)]
    return cfg, mesh, flags, pspecs, params, opt, batches


def _baseline(setup):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    step = jax.jit(make_train_step(cfg, opt, flags))
    opt_state, losses = opt.init(params), []
    for b in batches:
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    return params, losses


def _assert_matches(setup, params, losses):
    ref_params, ref_losses = _baseline(setup)
    np.testing.assert_allclose(losses, ref_losses, atol=TOL, rtol=0)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=0)


def test_elastic_exact_matches_gspmd_baseline(setup):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    scfg = SyncConfig(strategy="exact", axis_names=("data",))
    state = init_dist_sync_state(scfg, mesh, params)
    step = jax.jit(make_elastic_train_step(cfg, opt, mesh, scfg, pspecs,
                                           flags))
    opt_state, losses = opt.init(params), []
    for b in batches:
        params, opt_state, state, m = step(params, opt_state, state, b)
        losses.append(float(m["loss"]))
    assert int(state["step"]) == N_STEPS
    _assert_matches(setup, params, losses)


def test_async_tau0_matches_gspmd_baseline(setup):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    acfg = AsyncConfig(tau_max=0, schedule="constant")
    state = init_async_state(acfg, mesh, params)
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    opt_state, losses = opt.init(params), []
    for b in batches:
        params, opt_state, state, m = step(params, opt_state, state, b)
        losses.append(float(m["loss"]))
        assert float(m["stale_gap2"]) == 0.0     # tau 0 == no staleness
        assert float(m["mean_tau"]) == 0.0
    assert int(state["step"]) == N_STEPS
    _assert_matches(setup, params, losses)


def test_async_tau0_empty_fault_plan_matches_baseline(setup):
    """An EMPTY fault plan is a true no-op: applying it to the tau table is
    bitwise-identity, and the tau0 run still matches synchronous SGD — the
    fault machinery adds nothing when nothing is scheduled."""
    from repro.faults import FaultPlan

    cfg, mesh, flags, pspecs, params, opt, batches = setup
    acfg = AsyncConfig(tau_max=0, schedule="constant")
    state = init_async_state(acfg, mesh, params)
    before = np.asarray(state["taus"])
    rewritten = FaultPlan().apply_to_taus(before, acfg.tau_max)
    np.testing.assert_array_equal(rewritten, before)
    state["taus"] = jnp.asarray(rewritten)
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    opt_state, losses = opt.init(params), []
    for b in batches:
        params, opt_state, state, m = step(params, opt_state, state, b)
        losses.append(float(m["loss"]))
    _assert_matches(setup, params, losses)


def test_async_tau0_crash_subst_matches_baseline(setup):
    """With one worker and tau 0 every step delivers exactly its own
    gradient, so the crash_subst renormalization is a multiply by n/cnt =
    1.0 — the guarded program must reproduce the baseline."""
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    acfg = AsyncConfig(tau_max=0, schedule="constant", crash_subst=True)
    state = init_async_state(acfg, mesh, params)
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    opt_state, losses = opt.init(params), []
    for b in batches:
        params, opt_state, state, m = step(params, opt_state, state, b)
        losses.append(float(m["loss"]))
        assert float(m["nonfinite"]) == 0.0
    _assert_matches(setup, params, losses)


def test_async_stale_diverges_but_bounded(setup):
    """tau_max > 0: the realized staleness honors the bound, the staleness
    gap is visible, and training still moves parameters."""
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    acfg = AsyncConfig(tau_max=3, schedule="uniform", seed=1)
    state = init_async_state(acfg, mesh, params)
    assert jax.tree.leaves(state["buf"])[0].shape[1] == 4  # tau_max + 1
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    p1, opt_state = params, opt.init(params)
    gaps = []
    for b in batches:
        p1, opt_state, state, m = step(p1, opt_state, state, b)
        assert np.isfinite(float(m["loss"]))
        assert 0.0 <= float(m["mean_tau"]) <= 3.0
        gaps.append(float(m["stale_gap2"]))
    assert max(gaps) > 0.0                       # staleness actually realized
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0


def test_async_ef_state_only_when_configured(setup):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    no_comp = init_async_state(AsyncConfig(tau_max=1), mesh, params)
    assert "err" not in no_comp
    no_ef = init_async_state(
        AsyncConfig(tau_max=1, compressor="topk", error_feedback=False),
        mesh, params, pspecs)
    assert "err" not in no_ef
    acfg = AsyncConfig(tau_max=1, compressor="topk", error_feedback=True,
                       topk_ratio=1 / 8)
    state = init_async_state(acfg, mesh, params, pspecs)
    assert "err" in state
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    p1, opt_state = params, opt.init(params)
    p1, opt_state, state, m = step(p1, opt_state, state, batches[0])
    # top-k keeps a nonzero residual the very first round
    err_norm = sum(float(jnp.sum(jnp.square(e)))
                   for e in jax.tree.leaves(state["err"]))
    assert err_norm > 0


# ---------------------------------------------------------------------------
# overlapped (fused compress-then-reduce) engine
# ---------------------------------------------------------------------------

def _run_async(setup, acfg):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    state = init_async_state(acfg, mesh, params,
                             pspecs if acfg.fused else None)
    step = jax.jit(make_async_train_step(cfg, opt, mesh, acfg, pspecs,
                                         flags))
    p, opt_state, traj = params, opt.init(params), []
    for b in batches:
        p, opt_state, state, m = step(p, opt_state, state, b)
        traj.append((float(m["loss"]),
                     [np.asarray(x) for x in jax.tree.leaves(p)]))
    return state, traj


def test_async_tau0_overlap_bitwise_equals_gspmd(setup):
    """The double-buffered dense take (prior-consume before deposit, own
    remainder after) is BITWISE the single-take program: tau_max=0 still
    reduces to synchronous SGD exactly, not just within tolerance."""
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    _, traj = _run_async(setup, AsyncConfig(tau_max=0, schedule="constant"))
    ref_params, ref_losses = _baseline(setup)
    np.testing.assert_array_equal([l for l, _ in traj], ref_losses)
    for a, b in zip(traj[-1][1], jax.tree.leaves(ref_params)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_async_state_layout_fused_vs_densified(setup):
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    fused = init_async_state(
        AsyncConfig(tau_max=2, compressor="topk", topk_ratio=1 / 8),
        mesh, params, pspecs)
    assert "acc" in fused and "buf" not in fused
    # delivery-indexed accumulator rings: (capacity, M, R) f32 per leaf,
    # in the leaf's row-space geometry (M * R == leaf size)
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(fused["acc"])
    assert len(flat_a) == len(flat_p)
    for p, a in zip(flat_p, flat_a):
        assert a.ndim == 3 and a.shape[0] == 3      # tau_max + 1 slots
        assert a.dtype == jnp.float32
        assert a.shape[1] * a.shape[2] == p.size
    legacy = init_async_state(
        AsyncConfig(tau_max=2, compressor="topk", overlap=False),
        mesh, params)
    assert "buf" in legacy and "acc" not in legacy
    with pytest.raises(ValueError):      # fused needs the payload geometry
        init_async_state(AsyncConfig(tau_max=2, compressor="topk"),
                         mesh, params)


@pytest.mark.parametrize("compressor", ["topk", "onebit"])
def test_async_overlap_matches_densified_engine(setup, compressor):
    """Pipelining must not change delivery semantics: the fused
    compress-then-reduce engine (compact wire + cr_reduce deposit into
    the delivery-indexed accumulator rings) and the overlap=False
    densified engine walk the SAME trajectory step-for-step at tau_max=3,
    for both compressors."""
    kw = dict(tau_max=3, schedule="uniform", seed=1, compressor=compressor,
              topk_ratio=1 / 8, track_gap=True)
    _, fused = _run_async(setup, AsyncConfig(overlap=True, **kw))
    _, legacy = _run_async(setup, AsyncConfig(overlap=False, **kw))
    for t, ((lf, pf), (ll, pl)) in enumerate(zip(fused, legacy)):
        assert lf == ll, f"loss diverged at step {t}"
        for a, b in zip(pf, pl):
            np.testing.assert_allclose(a, b, atol=TOL, rtol=0,
                                       err_msg=f"step {t}")


def test_async_overlap_noop_without_compressor(setup):
    """overlap=True with compressor='none' is the densified program (the
    dense wire cannot split its collective without doubling bytes), so
    the state layout and trajectory are identical to overlap=False."""
    cfg, mesh, flags, pspecs, params, opt, batches = setup
    on = AsyncConfig(tau_max=2, schedule="uniform", seed=3, overlap=True)
    off = AsyncConfig(tau_max=2, schedule="uniform", seed=3, overlap=False)
    assert not on.fused
    assert "buf" in init_async_state(on, mesh, params)
    _, a = _run_async(setup, on)
    _, b = _run_async(setup, off)
    for (la, pa), (lb, pb) in zip(a, b):
        assert la == lb
        for x, y in zip(pa, pb):
            np.testing.assert_array_equal(x, y)
