"""Serving correctness: prefill + decode_step must reproduce the full
forward logits at every decoded position, for every stack kind (attention,
MoE, SWA, hybrid mamba2+shared-attn, rwkv6, grouped local:global)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models.params import init_params

FLAGS = TF.RunFlags(remat=False, kv_cache_dtype=jnp.float32)
B, S = 2, 32
PRE = S - 4

ARCHS = ["qwen3-1.7b", "mixtral-8x7b", "zamba2-7b", "rwkv6-1.6b",
         "gemma3-27b", "musicgen-large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if arch == "gemma3-27b":
        # exercise the grouped scan + remainder path (2 groups + 1 extra)
        cfg = dataclasses.replace(cfg, n_layers=5, global_every=2)
    key = jax.random.PRNGKey(1)
    params = init_params(TF.model_defs(cfg), key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    full, _ = TF.forward(cfg, params, batch, FLAGS)

    def decode_errs():
        _, cache = TF.prefill(cfg, params, {"tokens": tokens[:, :PRE]}, S,
                              FLAGS)
        errs = []
        for t in range(PRE, S):
            lg, cache = TF.decode_step(cfg, params, cache,
                                       tokens[:, t:t + 1], FLAGS)
            if t + 1 < S:
                errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
        return errs

    # bf16 compute tolerance. Under full-suite CPU load the comparison is
    # occasionally noisy (thread-level reduction order can flip a near-tie
    # MoE route, mixtral especially), so retry before failing: a real
    # regression fails all attempts, a scheduling artifact does not.
    for _ in range(3):
        errs = decode_errs()
        if max(errs) < 0.15:
            break
    assert max(errs) < 0.15, (arch, errs)


def test_decode_cache_pos_advances():
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    cache = TF.init_cache(cfg, B, S, FLAGS)
    tok = jnp.zeros((B, 1), jnp.int32)
    _, cache = TF.decode_step(cfg, params, cache, tok, FLAGS)
    assert int(cache["pos"]) == 1
    _, cache = TF.decode_step(cfg, params, cache, tok, FLAGS)
    assert int(cache["pos"]) == 2
