"""Serving correctness: prefill + decode_step must reproduce the full
forward logits at every decoded position, for every stack kind (attention,
MoE, SWA, hybrid mamba2+shared-attn, rwkv6, grouped local:global) — and the
paged-cache continuous engine must reproduce the dense-cache legacy loop
BITWISE per request."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models.params import init_params

FLAGS = TF.RunFlags(remat=False, kv_cache_dtype=jnp.float32)
B, S = 2, 32
PRE = S - 4

ARCHS = ["qwen3-1.7b", "mixtral-8x7b", "zamba2-7b", "rwkv6-1.6b",
         "gemma3-27b", "musicgen-large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if arch == "gemma3-27b":
        # exercise the grouped scan + remainder path (2 groups + 1 extra)
        cfg = dataclasses.replace(cfg, n_layers=5, global_every=2)
    key = jax.random.PRNGKey(1)
    params = init_params(TF.model_defs(cfg), key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    full, _ = TF.forward(cfg, params, batch, FLAGS)

    def decode_errs():
        _, cache = TF.prefill(cfg, params, {"tokens": tokens[:, :PRE]}, S,
                              FLAGS)
        errs = []
        for t in range(PRE, S):
            lg, cache = TF.decode_step(cfg, params, cache,
                                       tokens[:, t:t + 1], FLAGS)
            if t + 1 < S:
                errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
        return errs

    # bf16 compute tolerance. Under full-suite CPU load the comparison is
    # occasionally noisy (thread-level reduction order can flip a near-tie
    # MoE route, mixtral especially), so retry before failing: a real
    # regression fails all attempts, a scheduling artifact does not.
    for _ in range(3):
        errs = decode_errs()
        if max(errs) < 0.15:
            break
    assert max(errs) < 0.15, (arch, errs)


def test_decode_cache_pos_advances():
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    cache = TF.init_cache(cfg, B, S, FLAGS)
    tok = jnp.zeros((B, 1), jnp.int32)
    _, cache = TF.decode_step(cfg, params, cache, tok, FLAGS)
    assert int(cache["pos"]) == 1
    _, cache = TF.decode_step(cfg, params, cache, tok, FLAGS)
    assert int(cache["pos"]) == 2


# ---------------------------------------------------------------------------
# paged-vs-dense parity: the continuous engine on the paged KV cache must be
# bitwise-identical, per request, to the dense-cache legacy B=1 loop
# ---------------------------------------------------------------------------

PS = 8  # page size; prompt lengths below are multiples of it, and the
# engine's gather width (max_pages_per_seq * PS) matches the dense max_len,
# so every fp reduction tree is identical to the legacy loop's


def _legacy_tokens(cfg, params, prompt, n_new, max_len):
    """B=1 dense-cache greedy loop (the oracle)."""
    from repro.dist.train import make_decode_step, make_prefill_step

    prefill = make_prefill_step(cfg, max_len, FLAGS)
    decode = make_decode_step(cfg, FLAGS)
    tok, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    out = [tok]
    for _ in range(n_new - 1):
        tok, cache = decode(params, cache, tok[:, None])
        out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))[0]


@pytest.mark.parametrize("arch,lens,gens,arrivals,slots", [
    # dense arch: mixed lengths, staggered admission, 2 shared slots
    ("qwen3-1.7b", (8, 16, 8), (5, 3, 6), (0, 0, 1), 2),
    # MoE: single request only — group-capacity routing couples batch rows,
    # so multi-request batches are not bitwise-comparable to B=1 loops
    ("mixtral-8x7b", (16,), (6,), (0,), 1),
])
def test_paged_engine_matches_dense_loop(arch, lens, gens, arrivals, slots):
    from repro.serve import (ContinuousScheduler, PagedCacheConfig, Request,
                             StepEngine)

    cfg = get_config(arch).reduced()
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(4))
    n_table = max(-(-(p + g) // PS) for p, g in zip(lens, gens))
    max_len = n_table * PS
    pcfg = PagedCacheConfig(page_size=PS, num_pages=slots * n_table,
                            max_requests=slots, max_pages_per_seq=n_table)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=s, dtype=np.int32)
               for s in lens]
    engine = StepEngine(cfg, params, pcfg, FLAGS)
    sched = ContinuousScheduler(engine)
    toks = sched.run([Request(rid=i, prompt=p, max_new=g, arrival=a)
                      for i, (p, g, a) in enumerate(
                          zip(prompts, gens, arrivals))])
    engine.alloc.check()
    assert engine.alloc.n_free == pcfg.num_pages
    for i, (p, g) in enumerate(zip(prompts, gens)):
        ref = _legacy_tokens(cfg, params, p, g, max_len)
        np.testing.assert_array_equal(toks[i], ref, err_msg=f"rid {i}")
