"""Checkpoint round-trips of the distributed training state.

The per-worker accumulators (EF residuals, elastic residuals, async delay
rings) are genuinely distinct data per shard — a checkpoint that silently
replicated or collapsed them would corrupt resumed runs.  These tests pin:

  * values survive ``save_checkpoint``/``load_checkpoint`` bit-exactly,
  * restoring with `dist.sharding.sync_state_specs` shardings lands every
    leaf back on the mesh with the intended sharding (worker dim over the
    data axes, rings/scalars replicated as declared),
  * the sync- and async-state layouts both round-trip (EF ``err``,
    elastic ``residual``, async ``buf`` rings + ``taus`` table).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.scheduler import SyncConfig
from repro.dist import sharding as SH
from repro.dist.async_engine import AsyncConfig, init_async_state
from repro.dist.train import init_dist_sync_state
from repro.jax_compat import make_mesh
from repro.models import transformer as TF
from repro.models.params import init_params, param_specs


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config
    cfg = get_config("qwen3-1.7b").reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params = init_params(defs, jax.random.PRNGKey(0))
    return mesh, pspecs, params


def _randomize(tree, seed=0):
    """Distinct nonzero leaves so a value mixup cannot pass silently."""
    leaves, treedef = jax.tree.flatten(tree)
    rng = np.random.default_rng(seed)
    out = []
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(jnp.asarray(
                rng.normal(size=leaf.shape).astype(np.float32)))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _roundtrip(tmp_path, mesh, state, specs):
    shardings = SH.named(mesh, specs)
    state = jax.tree.map(jax.device_put, state, shardings)
    save_checkpoint(str(tmp_path), 7, state)
    restored = load_checkpoint(str(tmp_path), 7, shardings=shardings)
    # values bit-exact
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # shardings intact (leaf-for-leaf against the declared specs)
    flat_r = jax.tree.leaves(restored)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_r) == len(flat_s)
    for leaf, spec in zip(flat_r, flat_s):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding == NamedSharding(mesh, spec), (leaf.shape, spec)
    return restored


@pytest.mark.parametrize("strategy", ["topk_ef", "elastic"])
def test_sync_state_roundtrip(tmp_path, setup, strategy):
    mesh, pspecs, params = setup
    scfg = SyncConfig(strategy=strategy, axis_names=("data",))
    state = _randomize(init_dist_sync_state(scfg, mesh, params))
    key = "err" if strategy == "topk_ef" else "residual"
    lead = jax.tree.leaves(state[key])[0].shape[0]
    assert lead == 1                       # worker dim == prod(data axes)
    specs = SH.sync_state_specs(state, pspecs, mesh)
    assert tuple(jax.tree.leaves(
        specs[key], is_leaf=lambda x: isinstance(x, P))[0])[0] == "data"
    _roundtrip(tmp_path, mesh, state, specs)


def test_async_state_roundtrip(tmp_path, setup):
    mesh, pspecs, params = setup
    acfg = AsyncConfig(tau_max=2, schedule="uniform", compressor="topk",
                       error_feedback=True, horizon=16, overlap=False)
    state = _randomize(init_async_state(acfg, mesh, params))
    buf0 = jax.tree.leaves(state["buf"])[0]
    assert buf0.shape[:2] == (1, 3)        # (workers, tau_max + 1, ...)
    specs = SH.sync_state_specs(state, pspecs, mesh)
    # ring entries: worker dim sharded, ring dim replicated
    spec0 = jax.tree.leaves(specs["buf"],
                            is_leaf=lambda x: isinstance(x, P))[0]
    assert tuple(spec0)[:2] == ("data", None)
    restored = _roundtrip(tmp_path, mesh, state, specs)
    # the tau table round-trips exactly (schedule reproducibility on resume)
    np.testing.assert_array_equal(np.asarray(restored["taus"]),
                                  np.asarray(state["taus"]))


def test_async_fused_state_roundtrip(tmp_path, setup):
    """The fused engine's delivery-indexed accumulator rings checkpoint
    too — a mid-flight stale message (already deposited, not yet taken)
    survives a restart.  The rings are REPLICATED (every worker has
    decompressed every received message), unlike the per-worker dense
    rings; the EF residuals stay worker-sharded."""
    mesh, pspecs, params = setup
    acfg = AsyncConfig(tau_max=2, schedule="uniform", compressor="topk",
                       error_feedback=True, horizon=16)
    assert acfg.fused
    state = _randomize(init_async_state(acfg, mesh, params, pspecs))
    acc0 = jax.tree.leaves(state["acc"])[0]
    assert acc0.ndim == 3 and acc0.shape[0] == 3   # (tau_max + 1, M, R)
    specs = SH.sync_state_specs(state, pspecs, mesh)
    assert tuple(jax.tree.leaves(specs["acc"], is_leaf=lambda x: isinstance(
        x, P))[0]) == ()                           # replicated
    assert tuple(jax.tree.leaves(specs["err"], is_leaf=lambda x: isinstance(
        x, P))[0])[0] == "data"                    # per-worker
    restored = _roundtrip(tmp_path, mesh, state, specs)
    np.testing.assert_array_equal(np.asarray(restored["taus"]),
                                  np.asarray(state["taus"]))


def test_roundtrip_without_shardings_keeps_values(tmp_path, setup):
    mesh, pspecs, params = setup
    state = _randomize(init_async_state(AsyncConfig(tau_max=1), mesh, params))
    save_checkpoint(str(tmp_path), 3, state)
    restored = load_checkpoint(str(tmp_path), 3)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
