"""Production sync-strategy semantics.

Cross-shard behaviour needs >1 device, which requires XLA_FLAGS before jax
initializes — so those cases run in a subprocess (see _run_multidev); the
gate/bucketing math is tested in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import (bucket_assignment, norm_gate_mask,
                                  static_gate_mask)


def test_bucket_assignment_contiguous_balanced():
    grads = {"a": jnp.zeros(100), "b": jnp.zeros(100), "c": jnp.zeros(100),
             "d": jnp.zeros(100)}
    assign = bucket_assignment(grads, 2)
    assert assign == [0, 0, 1, 1]
    assert bucket_assignment(grads, 4) == [0, 1, 2, 3]


def test_norm_gate_selects_largest_until_beta():
    norms = jnp.asarray([10.0, 1.0, 5.0, 0.1])
    mask = np.asarray(norm_gate_mask(norms, beta=0.6))
    # 10 alone is 10/16.1 = 62% >= 60% -> only bucket 0
    assert mask.tolist() == [True, False, False, False]
    mask = np.asarray(norm_gate_mask(norms, beta=0.95))
    assert mask.tolist() == [True, True, True, False]


def test_norm_gate_budget_forces_full_sync():
    norms = jnp.asarray([10.0, 1.0, 5.0, 0.1])
    mask = np.asarray(norm_gate_mask(norms, beta=0.1, budget_b2=4.0,
                                     gap2=jnp.asarray(9.0)))
    assert mask.all()


def test_static_gate_round_robin():
    assert static_gate_mask(0, 8, 4) == [True, False, False, False] * 2
    assert static_gate_mask(3, 8, 4) == [False, False, False, True] * 2
    # every bucket is synced within one period
    synced = set()
    for phase in range(4):
        for b, m in enumerate(static_gate_mask(phase, 8, 4)):
            if m:
                synced.add(b)
    assert synced == set(range(8))


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.scheduler import SyncConfig, init_sync_state, sync_gradients
    # mesh/shard_map API drift (AxisType, check_vma) is absorbed by the shim
    from repro.jax_compat import make_mesh, shard_map

    mesh = make_mesh((8,), ("data",))
    P_ = P
    key = jax.random.PRNGKey(0)
    # per-shard gradients: shard i holds g_i; we stack on a leading axis and
    # let shard_map hand each shard its slice.
    G = {"w1": jax.random.normal(key, (8, 16, 64)),
         "w2": jax.random.normal(jax.random.fold_in(key, 1), (8, 32, 8))}
    specs = {"w1": P(None, None), "w2": P(None, None)}

    def run(strategy, **kw):
        scfg = SyncConfig(strategy=strategy, axis_names=("data",), **kw)

        def local(gstack):
            g = jax.tree.map(lambda x: x[0], gstack)
            state = init_sync_state(scfg, g)
            synced, state, metrics = sync_gradients(scfg, g, state,
                                                    specs=specs)
            # second round to exercise state carry
            synced2, state, metrics = sync_gradients(scfg, g, state,
                                                     specs=specs)
            return synced, synced2, metrics

        fn = shard_map(local, mesh,
                       (jax.tree.map(lambda _: P("data"), G),),
                       (P(), P(), P()), check=False)
        return fn(G)

    mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), G)

    # exact == plain mean (atol: pmean reduction order differs per backend)
    s1, s2, _ = run("exact")
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(mean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)
    print("exact OK")

    # topk_ef: two rounds of payload+carry must approach the mean; the
    # telescoping identity sum(applied) + mean(err) == sum(mean grads)
    s1, s2, m = run("topk_ef", topk_ratio=0.25)
    applied = jax.tree.map(lambda a, b: a + b, s1, s2)
    target = jax.tree.map(lambda x: 2 * x, mean)
    num = sum(float(jnp.sum((a - t) ** 2))
              for a, t in zip(jax.tree.leaves(applied),
                              jax.tree.leaves(target)))
    den = sum(float(jnp.sum(t ** 2)) for t in jax.tree.leaves(target))
    rel = (num / den) ** 0.5
    assert rel < 0.9, rel   # EF catches up (residual bounded)
    assert float(m["gap2_over_alpha2"]) >= 0.0
    print("topk_ef OK rel", rel)

    s1, s2, m = run("onebit_ef")
    print("onebit_ef OK")

    # elastic norm-gated: synced+residual accounting: after 2 rounds the
    # total applied + mean residual == 2 * mean
    s1, s2, m = run("elastic", n_buckets=2, beta=0.5, gate="norm")
    print("elastic OK gap2", float(m["gap2_over_alpha2"]))
    print("ALL_MULTIDEV_OK")
""")


@pytest.mark.slow
def test_strategies_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "ALL_MULTIDEV_OK" in r.stdout, (r.stdout, r.stderr)
