"""Substrate tests: optimizers, data pipeline determinism, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticLMDataset
from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         global_norm, momentum, sgd)
from repro.optim.schedules import cosine_decay, warmup_cosine


def _quad_min(opt, steps=300):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_sgd_converges():
    assert _quad_min(sgd(0.1)) < 1e-3


def test_momentum_converges():
    assert _quad_min(momentum(0.05, 0.9)) < 1e-3


def test_adam_converges():
    assert _quad_min(adam(0.1), steps=600) < 1e-2


def test_momentum_matches_manual():
    opt = momentum(0.1, 0.9)
    p = {"w": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([1.0])}
    upd1, s = opt.update(g, s, p)          # mu = 1 -> upd = -0.1
    np.testing.assert_allclose(upd1["w"], [-0.1])
    upd2, s = opt.update(g, s, p)          # mu = 1.9 -> upd = -0.19
    np.testing.assert_allclose(upd2["w"], [-0.19], rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-6)


def test_schedules_monotone():
    c = cosine_decay(1.0, 100)
    assert float(c(0)) > float(c(50)) > float(c(100))
    w = warmup_cosine(1.0, 10, 100)
    assert float(w(0)) < float(w(10))
    np.testing.assert_allclose(float(w(10)), 1.0, rtol=1e-5)


def test_data_deterministic_and_learnable():
    ds = SyntheticLMDataset(vocab_size=64, seq_len=32, batch_size=4, seed=1)
    b1, b2 = ds.batch(7), ds.batch(7)
    assert bool(jnp.all(b1["tokens"] == b2["tokens"]))
    b3 = ds.batch(8)
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))
    # labels are tokens shifted by one
    full1 = ds.batch(7)
    assert bool(jnp.all(full1["labels"][:, :-1] == full1["tokens"][:, 1:]))


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.int32)}]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree)
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        back = load_checkpoint(d, 7)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
