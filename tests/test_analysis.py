"""Tests for the static-analysis subsystem (`repro.analysis`).

The golden file ``tests/golden/collective_inventory.json`` pins the exact
collective inventory (primitive counts AND bytes-on-wire) of every
strategy-tagged entry point at audit scale — a program change that adds,
drops, or resizes a collective fails here before it ships.  Regenerate
with the snippet in the golden file's test after reviewing the diff.
"""
import ast
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit, lint, rings
from repro.analysis.findings import Finding, Report, load_baseline
from repro.core.delivery import DROPPED

HERE = os.path.dirname(__file__)
REPO = os.path.dirname(HERE)
GOLDEN = os.path.join(HERE, "golden", "collective_inventory.json")


# ---------------------------------------------------------------------------
# findings / baseline plumbing
# ---------------------------------------------------------------------------

def test_fingerprint_ignores_line_numbers():
    a = Finding("lint", "r", "f.py:fn", "d", line=10)
    b = Finding("lint", "r", "f.py:fn", "d", line=99)
    c = Finding("lint", "r", "f.py:fn", "other")
    assert a.fingerprint == b.fingerprint != c.fingerprint


def test_report_new_findings_respects_baseline():
    f1 = Finding("lint", "r", "a", "x")
    f2 = Finding("lint", "r", "b", "y")
    rep = Report(findings=[f1, f2])
    assert rep.new_findings({f1.fingerprint}) == [f2]
    assert rep.new_findings(set()) == [f1, f2]


def test_write_baseline_refuses_unjustified(tmp_path):
    """--update-baseline without a real justification is refused; a TODO
    placeholder does not count as one."""
    from repro.analysis.findings import unjustified_entries, write_baseline

    path = str(tmp_path / "baseline.json")
    f1 = Finding("lint", "r", "a", "x")
    with pytest.raises(ValueError, match="without a real justification"):
        write_baseline(path, [f1])
    with pytest.raises(ValueError, match="without a real justification"):
        write_baseline(path, [f1], {"*": "TODO: justify or fix"})
    assert not os.path.exists(path)           # refused writes write nothing

    write_baseline(path, [f1], {"*": "known wart, tracked in ROADMAP"})
    assert load_baseline(path) == {f1.fingerprint}
    assert unjustified_entries(path) == []


def test_write_baseline_preserves_handwritten_justifications(tmp_path):
    from repro.analysis.findings import unjustified_entries, write_baseline

    path = str(tmp_path / "baseline.json")
    f1 = Finding("lint", "r", "a", "x")
    f2 = Finding("audit", "s", "b", "y")
    write_baseline(path, [f1], {"*": "hand-reviewed: benign"})
    # a rewrite adding f2 keeps f1's text and only needs to justify f2
    write_baseline(path, [f1, f2], {f2.fingerprint: "new, also benign"})
    entries = {e["fingerprint"]: e
               for e in json.load(open(path))["accepted"]}
    assert entries[f1.fingerprint]["justification"] == \
        "hand-reviewed: benign"
    assert entries[f2.fingerprint]["justification"] == "new, also benign"

    # doctor a TODO into the checked-in file: CI's gate must flag it
    entries[f1.fingerprint]["justification"] = "TODO: later"
    with open(path, "w") as fh:
        json.dump({"accepted": list(entries.values())}, fh)
    bad = unjustified_entries(path)
    assert [e["fingerprint"] for e in bad] == [f1.fingerprint]


# ---------------------------------------------------------------------------
# golden collective inventory (tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def strategy_inventories():
    # regenerate GOLDEN by running this loop and dumping the result (see
    # the generator stanza in the repo's README "Correctness tooling")
    from repro.analysis import entrypoints as EP
    out = {}
    for e in EP.make_registry(1):
        if not e.strategy:
            continue
        inv = audit.collective_inventory(audit.trace_entry(e).jaxpr)
        out[e.strategy] = {
            "entry": e.name,
            "collectives": {k: v for k, v in inv.items()
                            if k != "wire_bytes"},
            "wire_bytes": inv["wire_bytes"],
        }
    return out


@pytest.mark.slow
def test_golden_collective_inventory(strategy_inventories):
    with open(GOLDEN) as fh:
        golden = json.load(fh)["strategies"]
    assert strategy_inventories == golden


@pytest.mark.slow
def test_compressed_strictly_beats_sync_on_wire(strategy_inventories):
    """The paper's communication reduction, on the traced programs: both
    compressed sync strategies put strictly fewer bytes on the wire than
    the dense baseline — and not marginally so."""
    sync = strategy_inventories["sync"]["wire_bytes"]
    assert strategy_inventories["topk_ef"]["wire_bytes"] < sync / 10
    assert strategy_inventories["onebit_ef"]["wire_bytes"] < sync / 2
    assert sync > 0


@pytest.mark.slow
def test_track_gap_costs_a_full_width_pmean(strategy_inventories):
    """The gap2 metric's cost is visible and gated: with track_gap the
    compressed strategy pays MORE than dense sync (metric pmean + its own
    gathers); without it, 85x less.  This pins the SyncConfig.track_gap
    satellite — regressing the gate turns the wire win back off."""
    gap = strategy_inventories["topk_ef+gap"]["wire_bytes"]
    hot = strategy_inventories["topk_ef"]["wire_bytes"]
    sync = strategy_inventories["sync"]["wire_bytes"]
    assert gap > sync > hot


@pytest.mark.slow
def test_async_fused_wire_beats_sync(strategy_inventories):
    """The fused compress-then-reduce path closed the ROADMAP gap: the
    compressed async engine's wire is one compact all-gather per step
    (cr_reduce consumes the payload ring), so tau=4 top-k traces to ~8x
    fewer bytes than dense sync at ratio 1/8 — it no longer densifies
    into a full-width pmean.  Dense async (no compressor) and the
    overlap=False escape hatch still pay exactly the sync-sized wire:
    delivery semantics are unchanged, only the compressed wire shrank."""
    a0 = strategy_inventories["async_tau0"]["wire_bytes"]
    a4 = strategy_inventories["async_tau4"]["wire_bytes"]
    sync = strategy_inventories["sync"]["wire_bytes"]
    assert a0 == a4                          # dense: tau never changes wire
    assert abs(a0 - sync) < 0.01 * sync
    dens = strategy_inventories["async_tau4_topk_ef_densified"]["wire_bytes"]
    assert abs(dens - sync) < 0.01 * sync    # escape hatch: dense wire
    topk = strategy_inventories["async_tau4_topk_ef"]["wire_bytes"]
    onebit = strategy_inventories["async_tau4_onebit_ef"]["wire_bytes"]
    assert topk < sync / 4                   # is sync/8 at ratio 1/8
    assert onebit < sync / 4                 # bool bitmap: 1 byte/elt
    assert topk > 0 and onebit > 0


def test_wire_comparison_flags_regression():
    inv = {
        "a": {"strategy": "sync", "collectives": {"wire_bytes": 100.0}},
        "b": {"strategy": "topk_ef", "collectives": {"wire_bytes": 100.0}},
    }
    findings, by = audit.wire_comparison(inv)
    assert [f.rule for f in findings] == ["compressed-not-better"]
    assert by == {"sync": 100.0, "topk_ef": 100.0}


# ---------------------------------------------------------------------------
# jaxpr walking primitives
# ---------------------------------------------------------------------------

def test_inventory_sees_collectives_inside_scan_and_shard_map():
    from repro.jax_compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1,), ("d",))

    def body(x):
        def inner(c, _):
            return c + jax.lax.pmean(x, axis_name="d"), None
        out, _ = jax.lax.scan(inner, x, None, length=3)
        return out

    fn = shard_map(body, mesh, (P("d"),), P("d"))
    closed = jax.make_jaxpr(fn)(jnp.zeros(4, jnp.float32))
    inv = audit.collective_inventory(closed.jaxpr)
    assert inv.get("psum", {}).get("count") == 1      # scan body counts once
    assert inv["wire_bytes"] == 2.0 * 4 * 4           # all-reduce factor 2x


def test_callback_detector():
    def f(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v) * 2, jax.ShapeDtypeStruct((), x.dtype), x)
        return y + 1

    closed = jax.make_jaxpr(f)(jnp.zeros(()))
    assert audit.find_callbacks(closed.jaxpr)
    closed2 = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros(()))
    assert not audit.find_callbacks(closed2.jaxpr)


def test_jaxpr_hash_stable_across_traces():
    f = lambda x: jnp.sin(x) + 1
    h1 = audit.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros(3)).jaxpr)
    h2 = audit.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros(3)).jaxpr)
    h3 = audit.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros(4)).jaxpr)
    assert h1 == h2 != h3


def test_donation_audit_realizes_alias():
    def step(params, x):
        return jax.tree.map(lambda p: p + x, params), x

    params = {"w": jnp.zeros((128, 128))}
    compiled = jax.jit(step, donate_argnums=(0,)).lower(
        params, jnp.ones(())).compile()
    assert compiled.memory_analysis().alias_size_in_bytes > 0


# ---------------------------------------------------------------------------
# schedules satellite: hoisted constant + no per-call allocation
# ---------------------------------------------------------------------------

def test_constant_schedule_returns_hoisted_array():
    from repro.optim.schedules import constant
    sched = constant(0.1)
    assert sched(0) is sched(1) is sched(100)         # one closed-over array
    assert float(sched(0)) == pytest.approx(0.1)


def test_constant_schedule_no_retrace_across_steps():
    from repro.optim import sgd
    from repro.optim.schedules import constant
    opt = sgd(constant(0.1))
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    traces = []
    for step in (0, 1):
        state["count"] = jnp.asarray(step, jnp.int32)
        traces.append(audit.jaxpr_hash(jax.make_jaxpr(
            lambda p, s: opt.update(jax.tree.map(jnp.zeros_like, p), s, p)
        )(params, state).jaxpr))
    assert traces[0] == traces[1]


# ---------------------------------------------------------------------------
# ring model checker
# ---------------------------------------------------------------------------

def test_delivery_rings_exhaustive_small():
    findings, stats = rings.check_gradient_rings(2, 2, 6)
    assert findings == []
    assert stats["schedules"] == 4 ** 6               # {DROPPED,0,1,2}^6


def test_negative_control_capacity_short_by_one():
    """cap = tau_max (one slot short) MUST alias — the checker has teeth."""
    taus = rings.enumerate_schedules(2, 6, rings=1, crashes=False)
    res = rings.prove_ring_schedules(taus, 2, "t")
    assert any(f.rule in ("slot-alias", "mistimed-delivery")
               for f in res.findings)
    assert rings.check_negative_control(2, 6) == []   # wrapper agrees


def test_reference_model_matches_closed_form():
    # msg0 due 2, msg1 due 1, msg2 dropped, msg3 due 4 (beyond the horizon
    # — still in flight, not delivered, not lost)
    model = rings.simulate_ring_model([2, 0, DROPPED, 1], cap=3)
    assert model["violations"] == []
    assert model["delivered"] == {0: 2, 1: 1}
    model = rings.simulate_ring_model([0, 0, 0], cap=1)
    assert model["delivered"] == {0: 0, 1: 1, 2: 2}
    # same-due messages legally share a slot (accumulate-then-deliver)
    model = rings.simulate_ring_model([1, 0], cap=2)   # dues 1 and 1
    assert model["violations"] == []
    assert model["delivered"] == {0: 1, 1: 1}


def test_reference_model_catches_capacity_violations():
    # tau exceeding cap - 1 must trip the model (premature take)
    model = rings.simulate_ring_model([1, 0], cap=1)
    assert any("mistimed" in v for v in model["violations"])
    model = rings.simulate_ring_model([2, 1, 0], cap=2)
    assert model["violations"] != []


def test_jnp_ground_truth_agrees():
    taus = rings.enumerate_schedules(1, 4, rings=1)[:, :, 0]
    assert rings.check_ground_truth(taus, cap=2, where="t") == []


def test_worker_ring_independence_witness():
    assert rings.check_worker_ring_independence(3, 2, 6) == []


def test_crash_rejoin_conservation_small():
    findings, stats = rings.check_crash_rejoin_conservation(2, 4)
    assert findings == []
    assert stats["configs"] > 0


def test_conservation_checker_catches_violations():
    p, t = 2, 3
    u = np.zeros((1, t, 1 + p, p), np.float32)
    alive = np.ones((1, t, p), bool)
    u[0, :, 0, :] = 1.0                                # all received
    u[0, :, 1:, :] = 1.0                               # rows sum to p == ok
    assert rings._conservation_violations("crash_subst", u, alive, "t") == []
    u[0, 1, 1, 0] = 0.0                                # drop mass
    bad = rings._conservation_violations("crash_subst", u, alive, "t")
    assert any(f.rule == "mass-not-conserved" for f in bad)
    u2 = u.copy()
    u2[0, :, 1:, :] = 1.0
    alive2 = alive.copy()
    alive2[0, 2, 1] = False                            # dead but row has mass
    bad2 = rings._conservation_violations("crash", u2, alive2, "t")
    assert any(f.rule == "dead-row-mass" for f in bad2)


def test_replica_version_ring():
    findings, stats = rings.check_replica_ring(1, 4, real_runs=32)
    assert findings == []
    assert stats["interleavings"] == 4 ** 4


def test_replica_model_catches_capacity_bug():
    # a replica with capacity tau_serve (one short) would serve a slot
    # already overwritten: emulate by shrinking cap in the model
    violations = rings.simulate_replica_model(
        [("publish",), ("publish",), ("refresh", 1)], tau_serve=1)
    assert violations == []


@pytest.mark.slow
def test_rings_full_run_clean():
    rep = rings.run(max_p=3, max_tau=2)
    assert rep.findings == []


# ---------------------------------------------------------------------------
# AST lint rules (on synthetic snippets)
# ---------------------------------------------------------------------------

def _lint_src(src, tmp_path, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return lint.lint_file(str(p), name)


def test_lint_prng_key_reuse(tmp_path):
    found = _lint_src("""
        import jax
        def sample_step(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
        """, tmp_path)
    assert [f.rule for f in found] == ["prng-key-reuse"]


def test_lint_prng_split_is_clean(tmp_path):
    found = _lint_src("""
        import jax
        def sample_step(key):
            a = jax.random.normal(key, (3,))
            key, sub = jax.random.split(key)
            b = jax.random.normal(key, (3,))
            return a + b
        """, tmp_path)
    assert found == []


def test_lint_host_sync_in_step(tmp_path):
    found = _lint_src("""
        import numpy as np
        def make_train_step(opt):
            def step(params, batch):
                loss = compute(params, batch)
                print(float(loss))
                arr = np.asarray(loss)
                return params, loss.item()
            return step
        """, tmp_path)
    rules = sorted(f.rule for f in found)
    assert rules.count("host-sync-in-step") == 3


def test_lint_np_on_traced(tmp_path):
    found = _lint_src("""
        import numpy as np
        def decode_body(x):
            return np.exp(x) + np.prod(x.shape)
        """, tmp_path)
    assert [f.rule for f in found] == ["np-on-traced"]  # np.prod whitelisted


def test_lint_missing_donation(tmp_path):
    found = _lint_src("""
        import jax
        step = make_train_step(cfg, opt)
        jitted = jax.jit(step)
        ok = jax.jit(step, donate_argnums=(0, 1))
        """, tmp_path)
    assert [f.rule for f in found] == ["missing-donation"]


def test_lint_pallas_tile_alignment(tmp_path):
    found = _lint_src("""
        from jax.experimental import pallas as pl
        def kernel_call(x):
            return launch(x, block_n=96)
        def kernel_call2(x):
            return launch(x, block_n=256, tile=(8, 128))
        """, tmp_path)
    assert [f.rule for f in found] == ["pallas-tile-misalign"]
    assert "96" in found[0].detail


def test_lint_factory_body_not_scanned(tmp_path):
    # build-time host math in a factory body is legal; the closure is not
    found = _lint_src("""
        import numpy as np
        def make_train_step(p):
            eye = np.eye(p)
            def step(params):
                return params
            return step
        """, tmp_path)
    assert found == []


def test_repo_lint_is_baselined():
    """Every current finding in src/repro is in the checked-in baseline —
    new hazards fail CI until fixed or justified."""
    rep = lint.run(repo_root=REPO)
    baseline = load_baseline(os.path.join(REPO, "analysis/baseline.json"))
    new = rep.new_findings(baseline)
    assert new == [], "\n".join(str(f) for f in new)


def test_hot_function_scoping():
    tree = ast.parse(textwrap.dedent("""
        def helper(): pass
        def make_thing():
            def inner(): pass
            return inner
        def train_step(): pass
        class Engine:
            def decode_once(self): pass
        """))
    names = {q for q, _ in lint.hot_functions(tree)}
    assert names == {"make_thing.inner", "train_step",
                     "Engine.decode_once"}
