"""Scan engine vs numpy oracle: step-for-step trajectory parity.

Both engines consume the same pre-drawn oblivious-adversary schedule and the
same gradient-key split chain, so for every relaxation kind the gap series,
recorded losses and final iterate must agree to fp32 accumulation tolerance
(reduction order differs: numpy row-gather sums vs MXU matvecs).
"""
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.problems import MLPClassification, Quadratic
from repro.core.sim import (Relaxation, simulate, simulate_shared_memory,
                            simulate_sweep)

P, T, ALPHA, DIM = 8, 60, 0.02, 32


@pytest.fixture(scope="module")
def prob():
    return Quadratic(dim=DIM, cond=8.0, sigma=1.0, seed=0)


@pytest.fixture(scope="module")
def x0():
    return np.ones(DIM, np.float32) * 2.0


KINDS = [
    ("sync", {}),
    ("crash", dict(f=3)),
    ("crash_subst", dict(f=3)),
    ("omission", dict(f=6, drop_prob=0.25)),
    ("async", dict(tau_max=3)),
    ("async_tau1", dict(tau_max=1)),
    ("ef_topk", dict(compressor=C.topk_compressor(0.25))),
    ("ef_onebit", dict(compressor=C.onebit_compressor())),
    ("elastic_norm", dict(beta=0.8)),
    ("elastic_variance", dict(drop_prob=0.3)),
    ("adversarial", dict(B_adv=20.0)),
]


def _relax(name, kw):
    kind = {"async_tau1": "async", "ef_topk": "ef_comp",
            "ef_onebit": "ef_comp"}.get(name, name)
    return Relaxation(kind, **kw)


def _assert_parity(a, b):
    np.testing.assert_allclose(a.gap2_over_alpha2, b.gap2_over_alpha2,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(a.losses, b.losses, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(a.grad_norms2, b.grad_norms2,
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(a.x_final, b.x_final, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("name,kw", KINDS, ids=[k[0] for k in KINDS])
def test_scan_matches_ref(prob, x0, name, kw):
    relax = _relax(name, kw)
    ref = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0, engine="ref")
    got = simulate(prob, relax, P, ALPHA, T, seed=3, x0=x0, engine="scan")
    _assert_parity(got, ref)


@pytest.mark.parametrize("seed", [0, 11])
def test_scan_matches_ref_across_seeds(prob, x0, seed):
    relax = Relaxation("elastic_variance", drop_prob=0.3)
    ref = simulate(prob, relax, P, ALPHA, T, seed=seed, x0=x0, engine="ref")
    got = simulate(prob, relax, P, ALPHA, T, seed=seed, x0=x0, engine="scan")
    _assert_parity(got, ref)


def test_scan_matches_ref_nonconvex(x0):
    mlp = MLPClassification(seed=0)
    x0m = np.asarray(mlp.init(seed=1))
    relax = Relaxation("async", tau_max=2)
    ref = simulate(mlp, relax, 4, 0.1, 40, seed=2, x0=x0m, engine="ref")
    got = simulate(mlp, relax, 4, 0.1, 40, seed=2, x0=x0m, engine="scan")
    _assert_parity(got, ref)


class _NoPresample:
    """View of a problem hiding the presample API — exercises both engines'
    fallback per-step key-split chain."""

    def __init__(self, inner):
        self._inner = inner
        self.dim = inner.dim

    def loss(self, x):
        return self._inner.loss(x)

    def grad(self, x):
        return self._inner.grad(x)

    def batch_grads(self, views, key):
        return self._inner.batch_grads(views, key)


def test_scan_matches_ref_without_presample(prob, x0):
    wrapped = _NoPresample(prob)
    relax = Relaxation("async", tau_max=2)
    ref = simulate(wrapped, relax, P, ALPHA, T, seed=3, x0=x0, engine="ref")
    got = simulate(wrapped, relax, P, ALPHA, T, seed=3, x0=x0, engine="scan")
    _assert_parity(got, ref)


def test_shared_memory_parity(prob, x0):
    ref = simulate_shared_memory(prob, P, 0.005, T, tau_max=3, seed=3, x0=x0,
                                 engine="ref")
    got = simulate_shared_memory(prob, P, 0.005, T, tau_max=3, seed=3, x0=x0,
                                 engine="scan")
    _assert_parity(got, ref)


def test_vmap_over_seeds_matches_single_runs(prob, x0):
    relax = Relaxation("async", tau_max=2)
    seeds = [0, 1, 2]
    batch = simulate_sweep(prob, relax, P, ALPHA, T, seeds, x0=x0)
    assert len(batch) == len(seeds)
    for s, res in zip(seeds, batch):
        single = simulate(prob, relax, P, ALPHA, T, seed=s, x0=x0,
                          engine="scan")
        np.testing.assert_allclose(res.gap2_over_alpha2,
                                   single.gap2_over_alpha2,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(res.x_final, single.x_final,
                                   rtol=1e-5, atol=1e-6)
    # different seeds => different trajectories
    assert not np.allclose(batch[0].x_final, batch[1].x_final)
