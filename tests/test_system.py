"""End-to-end behaviour tests for the paper's system.

These run the real trainer (launch.train main loop) at smoke scale and
assert the paper-level claims hold on the production path:
  * training under every sync strategy reduces loss,
  * the elastic strategies track a bounded consistency gap,
  * the perfectly-consistent baseline and the elastic path reach comparable
    loss (the paper's accuracy-recovery claim at smoke scale).
"""
import importlib.util
import os
import subprocess
import sys

import numpy as np
import pytest

# Triage of the seed failures: the thresholds never ran — the trainer exits
# with ModuleNotFoundError on `repro.dist` (sharding helpers + train-step
# builders were not seeded in this snapshot) before the first step.  Tracked
# in ROADMAP.md; these un-xfail automatically the moment repro.dist lands.
_DIST_MISSING = importlib.util.find_spec("repro.dist") is None
pytestmark = pytest.mark.xfail(
    condition=_DIST_MISSING, run=False, strict=False,
    reason="repro.dist is not seeded in this snapshot: repro.launch.train "
           "raises ModuleNotFoundError before training starts (see "
           "ROADMAP.md: seed repro.dist or drop the launch-path tests)")


def _launch(sync, steps=120, devices=4, extra=()):
    """Run the real launcher in a subprocess; returns its stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b-smoke", "--steps", str(steps),
           "--batch", "8", "--seq", "32", "--lr", "0.02", "--sync", sync,
           "--devices", str(devices), "--log-every", "20", *extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


def _run_train(sync, steps=120, devices=4, extra=()):
    out = _launch(sync, steps, devices, extra)
    losses = []
    for line in out.splitlines():
        if line.startswith("step"):
            losses.append(float(line.split("loss")[1].split()[0]))
    final = float(out.split("final loss")[1].split()[0])
    return losses, final


@pytest.mark.slow
def test_exact_training_reduces_loss():
    losses, final = _run_train("exact")
    assert final < losses[0] * 0.85, (losses[0], final)


@pytest.mark.slow
@pytest.mark.parametrize("sync", ["topk_ef", "onebit_ef", "elastic"])
def test_relaxed_strategies_recover_convergence(sync):
    """The paper's claim: relaxed consistency trains to comparable loss."""
    _, final_exact = _run_train("exact")
    _, final_relaxed = _run_train(sync)
    assert final_relaxed < final_exact * 1.35 + 0.3, (sync, final_exact,
                                                      final_relaxed)


@pytest.mark.slow
def test_async_resume_restores_engine_state(tmp_path):
    """Kill-and-resume on the async path: the checkpoint carries the delay
    rings / tau-table position with the params, so the restart picks up at
    the saved step instead of replaying the schedule from t=0."""
    def run(steps):
        return _launch("async", steps=steps, devices=2, extra=(
            "--tau-max", "2", "--async-schedule", "roundrobin",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"))

    run(8)
    out = run(16)
    assert "resumed from step 8" in out, out[-2000:]
    assert "final loss" in out


def _losses_by_step(out: str) -> dict:
    """Parse ``step N loss X`` lines; the LAST occurrence per step wins
    (a killed run replays steps since its checkpoint after the restart)."""
    losses = {}
    for line in out.splitlines():
        if line.startswith("step"):
            parts = line.split()
            losses[int(parts[1])] = float(parts[3])
    return losses


@pytest.mark.slow
def test_supervisor_restarts_sigkill_and_matches_oracle(tmp_path):
    """The tentpole end-to-end claim: a SIGKILL mid-run (from a fault plan)
    is survived by the supervisor — the child restarts from the latest
    valid checkpoint, and because data/taus/rings are all deterministic in
    (seed, step), the recovered trajectory is step-for-step the one an
    uninterrupted run produces (paper ``crash`` + recovery semantics)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    plan = str(tmp_path / "plan.json")
    subprocess.run([sys.executable, "-m", "repro.faults.plan",
                    "--out", plan, "--kill-at", "9"],
                   env=env, check=True, timeout=120)
    train = ["--arch", "qwen3-1.7b-smoke", "--steps", "16", "--batch", "8",
             "--seq", "32", "--lr", "0.02", "--sync", "async",
             "--devices", "2", "--tau-max", "2",
             "--async-schedule", "roundrobin", "--log-every", "1",
             "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "4"]
    sup = subprocess.run(
        [sys.executable, "-m", "repro.launch.supervisor",
         "--max-restarts", "2", "--backoff", "0.1",
         "--fault-plan", plan, "--", *train],
        env=env, capture_output=True, text=True, timeout=900)
    assert sup.returncode == 0, (sup.stdout[-2000:], sup.stderr[-2000:])
    assert "fault: SIGKILL at step 9" in sup.stdout
    assert "resumed from step 8" in sup.stdout, sup.stdout[-2000:]
    assert "[supervisor] child completed on attempt 1" in sup.stdout

    # the oracle: same plan, but --fault-attempt 1 means the kill (an
    # attempt-0 event) never fires — one uninterrupted run
    oracle = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *train[:-4],
         "--ckpt-dir", str(tmp_path / "ckpt_oracle"), "--ckpt-every", "4",
         "--fault-plan", plan, "--fault-attempt", "1"],
        env=env, capture_output=True, text=True, timeout=900)
    assert oracle.returncode == 0, (oracle.stdout[-2000:],
                                    oracle.stderr[-2000:])
    got, want = _losses_by_step(sup.stdout), _losses_by_step(oracle.stdout)
    assert set(got) == set(want) == set(range(16))
    for t in range(16):
        assert abs(got[t] - want[t]) < 1e-4, (t, got[t], want[t])
    final = float(sup.stdout.split("final loss")[1].split()[0])
    assert np.isfinite(final)


@pytest.mark.slow
def test_async_bounded_staleness_recovers_convergence():
    """Bounded staleness (tau_max=4, uniform schedule) still trains the
    real model to comparable loss on the launcher path — the elastic
    condition at work for the asynchronous relaxation."""
    _, final_exact = _run_train("exact")
    _, final_async = _run_train(
        "async", extra=("--tau-max", "4", "--async-schedule", "uniform"))
    assert final_async < final_exact * 1.35 + 0.3, (final_exact, final_async)
