"""End-to-end behaviour tests for the paper's system.

These run the real trainer (launch.train main loop) at smoke scale and
assert the paper-level claims hold on the production path:
  * training under every sync strategy reduces loss,
  * the elastic strategies track a bounded consistency gap,
  * the perfectly-consistent baseline and the elastic path reach comparable
    loss (the paper's accuracy-recovery claim at smoke scale).
"""
import importlib.util
import os
import subprocess
import sys

import pytest

# Triage of the seed failures: the thresholds never ran — the trainer exits
# with ModuleNotFoundError on `repro.dist` (sharding helpers + train-step
# builders were not seeded in this snapshot) before the first step.  Tracked
# in ROADMAP.md; these un-xfail automatically the moment repro.dist lands.
_DIST_MISSING = importlib.util.find_spec("repro.dist") is None
pytestmark = pytest.mark.xfail(
    condition=_DIST_MISSING, run=False, strict=False,
    reason="repro.dist is not seeded in this snapshot: repro.launch.train "
           "raises ModuleNotFoundError before training starts (see "
           "ROADMAP.md: seed repro.dist or drop the launch-path tests)")


def _launch(sync, steps=120, devices=4, extra=()):
    """Run the real launcher in a subprocess; returns its stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b-smoke", "--steps", str(steps),
           "--batch", "8", "--seq", "32", "--lr", "0.02", "--sync", sync,
           "--devices", str(devices), "--log-every", "20", *extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


def _run_train(sync, steps=120, devices=4, extra=()):
    out = _launch(sync, steps, devices, extra)
    losses = []
    for line in out.splitlines():
        if line.startswith("step"):
            losses.append(float(line.split("loss")[1].split()[0]))
    final = float(out.split("final loss")[1].split()[0])
    return losses, final


@pytest.mark.slow
def test_exact_training_reduces_loss():
    losses, final = _run_train("exact")
    assert final < losses[0] * 0.85, (losses[0], final)


@pytest.mark.slow
@pytest.mark.parametrize("sync", ["topk_ef", "onebit_ef", "elastic"])
def test_relaxed_strategies_recover_convergence(sync):
    """The paper's claim: relaxed consistency trains to comparable loss."""
    _, final_exact = _run_train("exact")
    _, final_relaxed = _run_train(sync)
    assert final_relaxed < final_exact * 1.35 + 0.3, (sync, final_exact,
                                                      final_relaxed)


@pytest.mark.slow
def test_async_resume_restores_engine_state(tmp_path):
    """Kill-and-resume on the async path: the checkpoint carries the delay
    rings / tau-table position with the params, so the restart picks up at
    the saved step instead of replaying the schedule from t=0."""
    def run(steps):
        return _launch("async", steps=steps, devices=2, extra=(
            "--tau-max", "2", "--async-schedule", "roundrobin",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"))

    run(8)
    out = run(16)
    assert "resumed from step 8" in out, out[-2000:]
    assert "final loss" in out


@pytest.mark.slow
def test_async_bounded_staleness_recovers_convergence():
    """Bounded staleness (tau_max=4, uniform schedule) still trains the
    real model to comparable loss on the launcher path — the elastic
    condition at work for the asynchronous relaxation."""
    _, final_exact = _run_train("exact")
    _, final_async = _run_train(
        "async", extra=("--tau-max", "4", "--async-schedule", "uniform"))
    assert final_async < final_exact * 1.35 + 0.3, (final_exact, final_async)
