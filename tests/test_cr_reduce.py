"""Parity and property tests for the fused compress-then-reduce kernels.

`kernels/cr_reduce` is the consume side of the overlapped async engine:
it reduces a panel of S compact compressed messages (top-k vals/idx or
one-bit sign/mean) straight to the dense weighted sum, without ever
materializing the (S, M, R) dense panel.  Three things are pinned here:

  * the Pallas kernels (interpret mode off-TPU) match the jnp oracles
    bitwise-ish (f32 accumulate either way) across dtypes and shapes,
    including non-lane-aligned trailing dims;
  * fused compress-then-reduce of n workers' gradients equals the
    strawman compress -> densify -> dense mean, so swapping the engine's
    dense pmean for the fused path cannot change a trajectory;
  * `scheduler.ef_compress_leaf_compact`'s wire payload densifies to
    exactly `scheduler.ef_compress_leaf`'s payload (same residual too) —
    the compact wire form loses nothing relative to the legacy path.

Property tests need ``hypothesis`` (installed in CI; skipped elsewhere).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cr_reduce import ops as CR
from repro.kernels.cr_reduce.ref import (onebit_cr_deposit_ref,
                                         onebit_cr_reduce_ref,
                                         topk_cr_deposit_ref,
                                         topk_cr_reduce_ref)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # containers without hypothesis: CI still runs these
    HAVE_HYPOTHESIS = False


def _topk_panel(rng, s, m, r, k, dtype):
    vals = rng.standard_normal((s, m, k)).astype(dtype)
    idx = np.stack([
        np.stack([rng.choice(r, size=k, replace=False).astype(np.int32)
                  for _ in range(m)]) for _ in range(s)])
    w = rng.uniform(0.0, 1.5, size=(s,)).astype(np.float32)
    return jnp.asarray(vals), jnp.asarray(idx), jnp.asarray(w)


# interpret-mode kernel vs oracle; shapes cover lane-aligned and ragged R
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,m,r,k", [
    (1, 8, 128, 16),     # single message, aligned
    (3, 16, 100, 7),     # ragged R, k not a divisor
    (5, 8, 257, 1),      # prime R, k=1
    (2, 24, 64, 64),     # k == R (dense-as-sparse)
])
def test_topk_kernel_matches_ref(s, m, r, k, dtype):
    rng = np.random.default_rng(s * 1000 + r)
    vals, idx, w = _topk_panel(rng, s, m, r, k, dtype)
    got = CR.topk_reduce(vals, idx, w, r, impl="kernel")
    want = topk_cr_reduce_ref(vals, idx, w, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,m,r", [
    (1, 8, 128), (3, 16, 100), (4, 8, 257), (2, 32, 33),
])
def test_onebit_kernel_matches_ref(s, m, r, dtype):
    rng = np.random.default_rng(s * 7 + r)
    pos = jnp.asarray(rng.random((s, m, r)) > 0.5)
    means = jnp.asarray(rng.standard_normal((s, m, 2)).astype(dtype))
    w = jnp.asarray(rng.uniform(0.0, 1.5, size=(s,)).astype(np.float32))
    got = CR.onebit_reduce(pos, means, w, impl="kernel")
    want = onebit_cr_reduce_ref(pos, means, w, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_weights_mask_messages():
    """A zero weight is a dropped message; scaling a weight scales its
    contribution linearly — the delivery-mask contract the async engine
    leans on."""
    rng = np.random.default_rng(0)
    vals, idx, w = _topk_panel(rng, 4, 8, 64, 8, np.float32)
    base = np.asarray(topk_cr_reduce_ref(vals, idx, jnp.ones(4), 64))
    only2 = np.asarray(topk_cr_reduce_ref(
        vals, idx, jnp.asarray([0.0, 0.0, 1.0, 0.0]), 64))
    solo = np.asarray(topk_cr_reduce_ref(vals[2:3], idx[2:3],
                                         jnp.ones(1), 64))
    np.testing.assert_allclose(only2, solo, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(topk_cr_reduce_ref(vals, idx, 2.0 * jnp.ones(4), 64)),
        2.0 * base, atol=1e-5)


def test_zero_size_panels():
    assert CR.topk_reduce(jnp.zeros((0, 8, 4)), jnp.zeros((0, 8, 4),
                          jnp.int32), jnp.zeros((0,)), 32).shape == (8, 32)
    assert CR.topk_reduce(jnp.zeros((2, 8, 0)), jnp.zeros((2, 8, 0),
                          jnp.int32), jnp.ones((2,)), 0).shape == (8, 0)
    assert CR.onebit_reduce(jnp.zeros((0, 4, 16), bool),
                            jnp.zeros((0, 4, 2)),
                            jnp.zeros((0,))).shape == (4, 16)


# ---------------------------------------------------------------------------
# slot deposit: fused decompress into the delivery-indexed accumulator ring
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("cap,s,m,r,k", [
    (1, 2, 8, 128, 16),     # capacity-1 ring (tau_max = 0), aligned
    (4, 3, 16, 100, 7),     # ragged R
    (5, 5, 8, 257, 1),      # prime R, k=1, slot collisions likely
])
def test_topk_deposit_kernel_matches_ref(cap, s, m, r, k, dtype):
    rng = np.random.default_rng(cap * 100 + r)
    vals, idx, w = _topk_panel(rng, s, m, r, k, dtype)
    acc = jnp.asarray(rng.standard_normal((cap, m, r)).astype(np.float32))
    slots = jnp.asarray(rng.integers(0, cap, size=(s,)).astype(np.int32))
    got = CR.topk_deposit(acc, vals, idx, slots, w, impl="kernel")
    want = topk_cr_deposit_ref(acc, vals, idx, slots, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("cap,s,m,r", [
    (1, 2, 8, 128), (3, 4, 16, 100), (6, 3, 8, 33),
])
def test_onebit_deposit_kernel_matches_ref(cap, s, m, r):
    rng = np.random.default_rng(cap * 13 + r)
    pos = jnp.asarray(rng.random((s, m, r)) > 0.5)
    means = jnp.asarray(rng.standard_normal((s, m, 2)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.0, 1.5, size=(s,)).astype(np.float32))
    acc = jnp.asarray(rng.standard_normal((cap, m, r)).astype(np.float32))
    slots = jnp.asarray(rng.integers(0, cap, size=(s,)).astype(np.int32))
    got = CR.onebit_deposit(acc, pos, means, slots, w, impl="kernel")
    want = onebit_cr_deposit_ref(acc, pos, means, slots, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_deposit_accumulates_and_masks():
    """Two messages into the SAME slot accumulate on top of the slot's
    prior content; a zero weight is a no-op — the delivery semantics the
    engine's deposit-then-take protocol leans on."""
    rng = np.random.default_rng(7)
    vals, idx, _ = _topk_panel(rng, 2, 8, 64, 8, np.float32)
    acc = jnp.asarray(rng.standard_normal((3, 8, 64)).astype(np.float32))
    slots = jnp.asarray([1, 1], np.int32)
    out = topk_cr_deposit_ref(acc, vals, idx, slots, jnp.ones(2))
    want = np.asarray(acc).copy()
    want[1] += _densify_topk(vals[0], idx[0], 64)
    want[1] += _densify_topk(vals[1], idx[1], 64)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)
    noop = topk_cr_deposit_ref(acc, vals, idx, slots, jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(noop), np.asarray(acc))
    noop1 = onebit_cr_deposit_ref(
        acc, jnp.asarray(rng.random((2, 8, 64)) > 0.5),
        jnp.asarray(rng.standard_normal((2, 8, 2)).astype(np.float32)),
        slots, jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(noop1), np.asarray(acc))


def test_deposit_then_take_equals_reduce():
    """Depositing a panel into a zeroed slot and taking that slot equals
    the panel's fused reduce with the same weights — the identity that
    makes the engine's single-deposit protocol equivalent to a per-step
    re-reduce."""
    rng = np.random.default_rng(11)
    vals, idx, w = _topk_panel(rng, 4, 8, 96, 12, np.float32)
    acc = jnp.zeros((5, 8, 96))
    slots = jnp.full((4,), 2, np.int32)
    out = topk_cr_deposit_ref(acc, vals, idx, slots, w)
    np.testing.assert_allclose(
        np.asarray(out[2]),
        np.asarray(topk_cr_reduce_ref(vals, idx, w, 96)), atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(out)[[0, 1, 3, 4]], np.zeros((4, 8, 96)))


# ---------------------------------------------------------------------------
# compact wire form vs the legacy densified compression
# ---------------------------------------------------------------------------

def _densify_topk(vals, idx, r):
    m, k = vals.shape
    out = np.zeros((m, r), np.float32)
    np.add.at(out, (np.arange(m)[:, None], np.asarray(idx)),
              np.asarray(vals, np.float32))
    return out


@pytest.mark.parametrize("method", ["topk", "onebit"])
def test_compact_densifies_to_legacy_payload(method):
    """ef_compress_leaf_compact's wire payload reconstructs bitwise to
    ef_compress_leaf's densified payload, and both leave the identical EF
    residual — the fused engine transmits exactly what the legacy engine
    would have."""
    from jax.sharding import PartitionSpec as P
    from repro.core.scheduler import ef_compress_leaf, ef_compress_leaf_compact
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((24, 40)).astype(np.float32))
    err = jnp.asarray(rng.standard_normal((24, 40)).astype(np.float32))
    spec = P("model", None)
    ratio = 1 / 8
    dense, err_d = ef_compress_leaf(g, err, spec, method, ratio)
    payload, err_c = ef_compress_leaf_compact(g, err, spec, method, ratio)
    np.testing.assert_array_equal(np.asarray(err_d), np.asarray(err_c))
    if method == "topk":
        recon = _densify_topk(payload["vals"], payload["idx"], 40)
    else:
        recon = np.where(np.asarray(payload["pos"]),
                         np.asarray(payload["means"])[:, 0:1],
                         np.asarray(payload["means"])[:, 1:2])
    np.testing.assert_array_equal(recon, np.asarray(dense))


# ---------------------------------------------------------------------------
# hypothesis: fused compress-then-reduce == compress -> densify -> mean
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 5), m=st.integers(1, 6), r=st.integers(1, 40),
           seed=st.integers(0, 1000), method=st.sampled_from(
               ["topk", "onebit"]))
    def test_fused_equals_dense_mean_property(n, m, r, seed, method):
        """For any panel of n workers' gradients: compress each worker's
        rows to the compact wire form, fused-reduce with weights 1/n, and
        you get exactly the mean of the densified compressed payloads —
        the invariant that makes the overlapped engine's delivery a
        drop-in for densify + pmean."""
        from jax.sharding import PartitionSpec as P
        from repro.core.scheduler import ef_compress_leaf_compact
        rng = np.random.default_rng(seed)
        rows = rng.standard_normal((n, m, r)).astype(np.float32)
        spec = P("model", None)
        payloads = [ef_compress_leaf_compact(
            jnp.asarray(rows[i]), jnp.zeros((m, r)), spec, method, 1 / 4)[0]
            for i in range(n)]
        w = jnp.full((n,), 1.0 / n)
        if method == "topk":
            fused = CR.topk_reduce(
                jnp.stack([p_["vals"] for p_ in payloads]),
                jnp.stack([p_["idx"] for p_ in payloads]), w, r)
            dense = np.mean([_densify_topk(p_["vals"], p_["idx"], r)
                             for p_ in payloads], axis=0)
        else:
            fused = CR.onebit_reduce(
                jnp.stack([p_["pos"] for p_ in payloads]),
                jnp.stack([p_["means"] for p_ in payloads]), w)
            dense = np.mean([np.where(np.asarray(p_["pos"]),
                                      np.asarray(p_["means"])[:, 0:1],
                                      np.asarray(p_["means"])[:, 1:2])
                             for p_ in payloads], axis=0)
        np.testing.assert_allclose(np.asarray(fused), dense,
                                   atol=1e-5, rtol=1e-5)
