"""MoE routing invariants (GShard capacity dispatch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import capacity, moe_block, route
from repro.models.params import init_params
from repro.models.transformer import model_defs


def _router_inputs(g=2, t=64, e=8, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (g, t, e))


@pytest.mark.parametrize("k", [1, 2, 4])
def test_capacity_never_exceeded(k):
    logits = _router_inputs()
    cap = capacity(64, k, 8, 1.25)
    dispatch, combine, aux = route(logits, k, cap)
    # per-(group, expert, slot): at most one token
    per_slot = jnp.sum(dispatch, axis=1)          # (G, E, C)
    assert float(jnp.max(per_slot)) <= 1.0 + 1e-6
    # per-token: at most k dispatched copies
    per_token = jnp.sum(dispatch, axis=(2, 3))    # (G, T)
    assert float(jnp.max(per_token)) <= k + 1e-6


def test_combine_weights_normalized():
    logits = _router_inputs()
    cap = capacity(64, 2, 8, 1.25)
    dispatch, combine, aux = route(logits, 2, cap)
    w = jnp.sum(combine, axis=(2, 3))             # (G, T) sum of gate weights
    assert float(jnp.max(w)) <= 1.0 + 1e-5
    # combine is nonzero only where dispatch is
    assert float(jnp.max(jnp.where(dispatch == 0, combine, 0.0))) < 1e-6


def test_aux_loss_minimized_by_uniform_router():
    e = 8
    uniform = jnp.zeros((2, 64, e))
    skewed = jnp.zeros((2, 64, e)).at[..., 0].set(10.0)
    cap = capacity(64, 2, e, 1.25)
    _, _, aux_u = route(uniform, 2, cap)
    _, _, aux_s = route(skewed, 2, cap)
    assert float(aux_s) > float(aux_u)


def test_moe_block_capacity_drop_is_graceful():
    """With capacity factor << 1 tokens drop but outputs stay finite."""
    import dataclasses
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              capacity_factor=0.25)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"])  # first layer
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                                jnp.bfloat16)
    out, aux = moe_block(lp["moe"], cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
