"""Property tests for `repro.core.delivery` — the delay-ring / staleness /
delivery-tensor machinery shared by the simulator and the real-model async
engine.

Invariants under test:

  * delay rings deliver every deposit exactly once, exactly ``delay`` steps
    after it was made (conservation + bounded staleness),
  * one-hot delay masks partition the messages (summed over levels every
    entry is exactly 1 — "row-stochastic where required"),
  * tau schedules never exceed ``tau_max`` (crashed entries are DROPPED),
  * crash/crash_subst delivery tensors conserve gradient mass across
    workers (substitution makes every alive receiver's row sum equal the
    globally-received count), and the elastic_variance tensors are exactly
    mass-preserving (view rows sum to p, defer rows to 0).

The deterministic versions always run; the randomized versions need the
``hypothesis`` package (installed in CI; skipped where absent).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delivery as DLV
from repro.core.sim_types import Relaxation, make_schedule

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # containers without hypothesis: CI still runs these
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# shared checkers (called from both deterministic and property tests)
# ---------------------------------------------------------------------------

def run_ring(delays: np.ndarray, tau_max: int):
    """Drive a delay ring with one message per (step, worker), delay table
    ``delays`` (T, p); message payload is one-hot in the source-step dim so
    each take reveals exactly which steps' messages were delivered."""
    t_steps, p = delays.shape
    cap = tau_max + 1
    ring = DLV.ring_init(cap, (p, t_steps))
    taken = []
    for t in range(t_steps + tau_max):
        if t < t_steps:
            payload = np.zeros((p, t_steps), np.float32)
            payload[np.arange(p), t] = 1.0
            d = np.clip(delays[t], 0, tau_max)
            alive = (delays[t] >= 0).astype(np.float32)
            for w in range(p):  # per-worker slot (workers delay independently)
                ring = ring.at[(t + int(d[w])) % cap, w].add(
                    payload[w] * alive[w])
        out, ring = DLV.ring_take(ring, t % cap)
        taken.append(np.asarray(out))
    return np.stack(taken)  # (T + tau_max, p, T): taken[t, w, s]


def check_ring_invariants(delays: np.ndarray, tau_max: int):
    taken = run_ring(delays, tau_max)
    t_steps, p = delays.shape
    for s in range(t_steps):
        for w in range(p):
            hits = np.nonzero(taken[:, w, s])[0]
            if delays[s, w] < 0:  # DROPPED: never delivered
                assert hits.size == 0
                continue
            # delivered exactly once (conservation) ...
            assert hits.size == 1 and taken[hits[0], w, s] == 1.0
            # ... exactly `delay` steps later, within the staleness bound
            assert hits[0] - s == delays[s, w] <= tau_max


def check_crash_conservation(kind: str, p: int, f: int, t_steps: int,
                             seed: int):
    relax = Relaxation(kind=kind, f=f)
    sched = make_schedule(relax, p, 4, t_steps, seed)
    u, new_alive = DLV.delivery_tensors(
        kind, p, t_steps,
        {k: jnp.asarray(v) for k, v in sched.per_step.items()},
        {k: jnp.asarray(v) for k, v in sched.per_run.items()},
        {"drop_prob": jnp.float32(0.3)})
    u = np.asarray(u)
    alive = np.asarray(new_alive)
    in_recv = u[:, 0, :]                       # x applies each grad <= once
    assert np.all((in_recv == 0) | (in_recv == 1))
    rows = u[:, 1:, :]
    # dead workers' rows are identically zero (no masking needed downstream)
    assert np.all(rows[~alive] == 0)
    row_sums = rows.sum(axis=2)
    if kind == "crash_subst":
        # substitution conserves mass: every alive receiver applies exactly
        # as many gradient-equivalents as there are globally-received grads
        expect = in_recv.sum(axis=1, keepdims=True)
        assert np.allclose(row_sums[alive],
                           np.broadcast_to(expect, row_sums.shape)[alive])
    else:
        # without substitution mass can only be lost, never created
        # (dead rows are zero, so the bound holds for every row)
        assert np.all(row_sums <= in_recv.sum(axis=1)[:, None] + 1e-6)


# ---------------------------------------------------------------------------
# deterministic tests (always run)
# ---------------------------------------------------------------------------

def test_ring_exactly_once_roundrobin():
    delays = DLV.make_tau_schedule("roundrobin", 3, 12, 4)
    check_ring_invariants(delays, 4)


def test_ring_exactly_once_crash_schedule():
    delays = DLV.make_tau_schedule("crash", 4, 10, 2, seed=3)
    assert (delays == DLV.DROPPED).any()       # somebody actually crashes
    check_ring_invariants(delays, 2)


def test_ring_tau0_is_synchronous():
    delays = np.zeros((6, 2), np.int32)
    taken = run_ring(delays, 0)
    for t in range(6):                         # delivered in the same step
        assert taken[t, :, t].sum() == 2


def test_delivery_plan_routes_every_live_message_once():
    """delivery_plan's (w_live, slots) reproduce the ring invariants: over
    a run, every live message is deposited into exactly the slot the dense
    rings would use ((t + tau) % cap), DROPPED ones get weight 0, and a
    message's slot is consumed at step t + tau — before anything else
    lands in it."""
    taus = DLV.make_tau_schedule("crash", 4, 12, 3, seed=5)
    cap = 4
    for t in range(12):
        w_live, slots = DLV.delivery_plan(jnp.asarray(taus), t, cap)
        w_live, slots = np.asarray(w_live), np.asarray(slots)
        for wk in range(4):
            tau = taus[t, wk]
            if tau == DLV.DROPPED:
                assert w_live[wk] == 0.0
            else:
                assert w_live[wk] == 1.0
                assert slots[wk] == (t + tau) % cap
                assert 0 <= tau <= 3         # consumed within the bound


def test_delay_masks_partition():
    rng = np.random.default_rng(0)
    delays = rng.integers(0, 5, size=(7, 3, 3))
    masks = DLV.delay_masks(delays, 5)
    assert masks.shape == (5, 7, 3, 3)
    np.testing.assert_array_equal(np.asarray(masks).sum(axis=0), 1.0)


def test_tau_schedules_bounded():
    for sched in DLV.TAU_SCHEDULES:
        taus = DLV.make_tau_schedule(sched, 4, 20, 3, seed=1)
        assert taus.shape == (20, 4) and taus.dtype == np.int32
        live = taus[taus != DLV.DROPPED]
        assert live.min() >= 0 and live.max() <= 3
        if sched not in ("crash", "rejoin"):   # only outages go DROPPED
            assert (taus >= 0).all()
    # determinism: one seed, one table
    a = DLV.make_tau_schedule("uniform", 4, 20, 3, seed=7)
    b = DLV.make_tau_schedule("uniform", 4, 20, 3, seed=7)
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError):
        DLV.make_tau_schedule("nope", 4, 20, 3)


def test_tau_schedule_shapes_and_styles():
    assert (DLV.make_tau_schedule("constant", 3, 5, 2) == 2).all()
    rr = DLV.make_tau_schedule("roundrobin", 3, 6, 2)
    assert rr[0, 0] == 0 and rr[1, 0] == 1 and rr[0, 1] == 1
    strag = DLV.make_tau_schedule("straggler", 4, 5, 3)
    assert (strag[:, -1] == 3).all() and (strag[:, :-1] == 0).all()


def test_elastic_variance_tensor_mass_neutral():
    relax = Relaxation(kind="elastic_variance", drop_prob=0.4)
    sched = make_schedule(relax, 5, 4, 9, seed=2)
    u, _ = DLV.delivery_tensors(
        "elastic_variance", 5, 9,
        {"drop_u": jnp.asarray(sched.per_step["drop_u"])}, {},
        {"drop_prob": jnp.float32(0.4)})
    u = np.asarray(u)
    assert np.allclose(u[:, 0, :], 1.0)            # x applies everything
    np.testing.assert_allclose(u[:, 1:6, :].sum(axis=2), 5.0, atol=1e-6)
    np.testing.assert_allclose(u[:, 6:, :].sum(axis=2), 0.0, atol=1e-6)


def test_crash_conservation_deterministic():
    check_crash_conservation("crash_subst", 6, 2, 12, seed=0)
    check_crash_conservation("crash", 6, 2, 12, seed=0)


# ---------------------------------------------------------------------------
# crash -> rejoin (recovery, not just failure)
# ---------------------------------------------------------------------------

def test_rejoin_schedule_outage_window():
    """Crashed workers actually come back: DROPPED only inside the
    window, normal bounded delays before AND after."""
    taus = DLV.make_tau_schedule("rejoin", 4, 30, 3, seed=2)
    down, back = 30 // 3, (2 * 30) // 3
    w = 3                                      # last worker crashes (p//4=1)
    assert (taus[down:back, w] == DLV.DROPPED).all()
    assert (taus[:down, w] >= 0).all()
    assert (taus[back:, w] >= 0).all()         # the worker rejoined
    assert (taus[:, :w] >= 0).all()            # survivors never drop


def test_ring_exactly_once_rejoin_schedule():
    """Exactly-once delivery holds across the crash->rejoin boundary: the
    outage loses exactly its own messages, re-entry duplicates nothing."""
    delays = DLV.make_tau_schedule("rejoin", 4, 18, 2, seed=3)
    assert (delays == DLV.DROPPED).any()
    assert (delays[-1] >= 0).all()             # everyone is back at the end
    check_ring_invariants(delays, 2)


def check_rejoin_conservation(kind: str, p: int, t_steps: int, seed: int):
    """`delivery_tensors` with a rejoin_step: the crash-model conservation
    laws extend over re-entry (alive rows after rejoin count full mass)."""
    rng = np.random.default_rng(seed)
    crash = rng.integers(0, t_steps, size=p)
    rejoin = np.minimum(crash + 1 + rng.integers(0, t_steps, size=p),
                        2 * t_steps)           # some never rejoin in-run
    per_run = {"crash_step": jnp.asarray(crash),
               "rejoin_step": jnp.asarray(rejoin),
               "hear_u": jnp.asarray(rng.uniform(size=(p, p)))}
    u, new_alive = DLV.delivery_tensors(kind, p, t_steps, {}, per_run, {})
    u, alive = np.asarray(u), np.asarray(new_alive)
    # rejoined workers are alive again
    ts = np.arange(t_steps)[:, None]
    np.testing.assert_array_equal(
        alive, ((crash[None] >= ts) & (crash[None] != ts))
        | (ts >= rejoin[None]))
    in_recv = u[:, 0, :]
    assert np.all((in_recv == 0) | (in_recv == 1))
    rows = u[:, 1:, :]
    assert np.all(rows[~alive] == 0)           # dead rows stay zero
    row_sums = rows.sum(axis=2)
    if kind == "crash_subst":
        expect = in_recv.sum(axis=1, keepdims=True)
        assert np.allclose(row_sums[alive],
                           np.broadcast_to(expect, row_sums.shape)[alive])
    else:
        assert np.all(row_sums <= in_recv.sum(axis=1)[:, None] + 1e-6)


def test_rejoin_mass_conservation_deterministic():
    check_rejoin_conservation("crash_subst", 6, 14, seed=0)
    check_rejoin_conservation("crash", 6, 14, seed=0)


def test_fault_plan_taus_keep_ring_invariants():
    """FaultPlan tau rewrites (crash/rejoin/delay/drop) only ever write
    legal values, so exactly-once delivery survives any plan."""
    from repro.faults import FaultEvent, FaultPlan

    base = DLV.make_tau_schedule("uniform", 4, 16, 3, seed=5)
    plan = FaultPlan(events=(
        FaultEvent(step=3, kind="crash", worker=1, duration=0),
        FaultEvent(step=9, kind="rejoin", worker=1),
        FaultEvent(step=2, kind="delay", worker=0, duration=4),
        FaultEvent(step=6, kind="drop", worker=2, duration=2),
    ))
    taus = plan.apply_to_taus(base, 3)
    assert (taus[3:9, 1] == DLV.DROPPED).all()
    np.testing.assert_array_equal(taus[9:, 1], base[9:, 1])  # delays resume
    assert (taus[2:6, 0] == 3).all()
    assert (taus[6:8, 2] == DLV.DROPPED).all()
    check_ring_invariants(taus, 3)


# ---------------------------------------------------------------------------
# hypothesis property tests (CI installs hypothesis in both lanes)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(p=st.integers(1, 5), t_steps=st.integers(1, 12),
           tau_max=st.integers(0, 4),
           sched=st.sampled_from(DLV.TAU_SCHEDULES),
           seed=st.integers(0, 10))
    def test_ring_delivery_property(p, t_steps, tau_max, sched, seed):
        delays = DLV.make_tau_schedule(sched, p, t_steps, tau_max, seed)
        check_ring_invariants(delays, tau_max)

    @settings(max_examples=30, deadline=None)
    @given(p=st.integers(1, 6), t_steps=st.integers(1, 16),
           tau_max=st.integers(0, 5),
           sched=st.sampled_from(DLV.TAU_SCHEDULES),
           seed=st.integers(0, 100))
    def test_tau_bounded_property(p, t_steps, tau_max, sched, seed):
        taus = DLV.make_tau_schedule(sched, p, t_steps, tau_max, seed)
        live = taus[taus != DLV.DROPPED]
        assert live.size == 0 or (0 <= live.min() and live.max() <= tau_max)

    @settings(max_examples=20, deadline=None)
    @given(levels=st.integers(1, 6), t_steps=st.integers(1, 8),
           p=st.integers(1, 5), seed=st.integers(0, 50))
    def test_delay_masks_partition_property(levels, t_steps, p, seed):
        rng = np.random.default_rng(seed)
        delays = rng.integers(0, levels, size=(t_steps, p, p))
        total = np.asarray(DLV.delay_masks(delays, levels)).sum(axis=0)
        np.testing.assert_array_equal(total, 1.0)

    @settings(max_examples=20, deadline=None)
    @given(kind=st.sampled_from(["crash", "crash_subst"]),
           p=st.integers(2, 7), data=st.data(),
           t_steps=st.integers(2, 14), seed=st.integers(0, 50))
    def test_crash_mass_conservation_property(kind, p, data, t_steps, seed):
        f = data.draw(st.integers(0, p - 1))
        check_crash_conservation(kind, p, f, t_steps, seed)
