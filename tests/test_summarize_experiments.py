"""Tests for `benchmarks.summarize_experiments` — the EXPERIMENTS.md block
regenerator.  Exercised against a doctored EXPERIMENTS.md and a scratch
dryrun dir: zero-artifact behavior, real rows, torn-artifact tolerance,
and `replace_block` idempotency (running the summarizer twice must be a
no-op, not an accretion)."""
import json

import pytest

import benchmarks.summarize_experiments as SE
from repro.cluster import analytic_record

DOC = """# Experiments

intro prose

<!-- DRYRUN_SUMMARY -->
stale dryrun content to be replaced

## Roofline

<!-- ROOFLINE_SUMMARY -->
stale roofline content

## Perf

perf notes stay untouched
"""


def _write_artifact(d, name, rec):
    (d / name).write_text(json.dumps(rec))


def _ok_record(arch="qwen3-1.7b-smoke", shape="train_4k"):
    rec = analytic_record(arch, shape)
    rec["compile_s"] = 1.2
    return rec


def test_blocks_with_zero_artifacts(tmp_path):
    empty = str(tmp_path / "none")
    assert "(no roofline rows yet)" in SE.roofline_block(empty)
    block = SE.dryrun_block(empty)
    assert "Totals: 0 ok, 0 skipped" in block


def test_roofline_block_with_rows(tmp_path):
    d = tmp_path / "dryrun"
    d.mkdir()
    _write_artifact(d, "a__train_4k__single__exact.json", _ok_record())
    md = tmp_path / "roofline.md"
    block = SE.roofline_block(str(d), str(md))
    assert "qwen3-1.7b-smoke" in block and "train_4k" in block
    assert "Dominant-term distribution" in block
    assert md.exists()                       # sidecar markdown written


def test_torn_artifact_skipped_with_warning(tmp_path):
    d = tmp_path / "dryrun"
    d.mkdir()
    (d / "torn__x__single__exact.json").write_text('{"arch": ')
    _write_artifact(d, "ok__train_4k__single__exact.json", _ok_record())
    with pytest.warns(UserWarning, match="unreadable dryrun artifact"):
        recs = SE.load("single", dryrun_dir=str(d))
    assert len(recs) == 1


def test_replace_block_idempotent(tmp_path):
    """Regenerating twice yields byte-identical text, and untouched
    sections survive."""
    exp = tmp_path / "EXPERIMENTS.md"
    exp.write_text(DOC)
    d = tmp_path / "dryrun"
    d.mkdir()
    _write_artifact(d, "a__train_4k__single__exact.json", _ok_record())
    md = str(tmp_path / "roofline.md")

    once = SE.summarize(str(exp), str(d), md)
    assert "stale dryrun content" not in once
    assert "stale roofline content" not in once
    assert "perf notes stay untouched" in once
    assert "## Perf" in once and "## Roofline" in once

    twice = SE.summarize(str(exp), str(d), md)
    assert twice == once
    assert exp.read_text() == once


def test_replace_block_unit():
    text = "head\n<!-- M -->\nold\n## Next\nrest"
    out = SE.replace_block(text, "M", "NEW\n")
    assert out == "head\n<!-- M -->\nNEW\n\n## Next\nrest"
    # markers that are absent leave the text alone
    assert SE.replace_block(text, "OTHER", "X") == text
