"""repro.dist API contract: every symbol the launch/test consumers import
must exist with the expected signature, so the `importorskip` guards in the
older tests can never silently drift back into dead skip-reasons.

Consumers pinned here:
  * repro.launch.train   — sharding.axis_sizes, train.make_train_step,
                           train.make_elastic_train_step
  * repro.launch.dryrun  — sharding.{axis_sizes, data_axes, named,
                           batch_spec, batch_specs, cache_specs,
                           opt_state_specs, make_act_rules},
                           train.{make_train_step, make_elastic_train_step,
                           make_prefill_step, make_decode_step}
  * repro.launch.serve   — train.{make_prefill_step, make_decode_step}
  * tests/test_archs_smoke — train.{loss_fn, make_train_step}
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import async_engine as AE
from repro.dist import sharding as SH
from repro.dist import train as DT


def params_of(fn) -> list:
    return list(inspect.signature(fn).parameters)


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------

def test_sharding_symbols_and_signatures():
    assert params_of(SH.axis_sizes) == ["mesh"]
    assert params_of(SH.data_axes) == ["mesh"]
    assert params_of(SH.named) == ["mesh", "spec_tree"]
    assert params_of(SH.batch_spec) == ["mesh", "global_batch"]
    assert params_of(SH.batch_specs) == ["cfg", "mesh", "batch"]
    assert params_of(SH.cache_specs) == ["cfg", "mesh", "cache"]
    assert params_of(SH.opt_state_specs) == ["opt_state", "pspecs"]
    sig = inspect.signature(SH.make_act_rules)
    assert params_of(SH.make_act_rules)[:2] == ["cfg", "mesh"]
    for kw in ("batch_size", "seq_len", "sequence_parallel", "batch_axes"):
        assert sig.parameters[kw].kind == inspect.Parameter.KEYWORD_ONLY, kw


def test_train_symbols_and_signatures():
    assert params_of(DT.loss_fn) == ["cfg", "params", "batch", "flags"]
    assert params_of(DT.make_train_step) == ["cfg", "opt", "flags",
                                             "grad_accum", "skip_nonfinite"]
    ep = params_of(DT.make_elastic_train_step)
    assert ep[:6] == ["cfg", "opt", "mesh", "scfg", "pspecs", "flags"]
    assert "static_phase" in ep and "grad_accum" in ep
    assert params_of(DT.init_dist_sync_state) == ["scfg", "mesh",
                                                  "params_like"]
    assert params_of(SH.sync_state_specs) == ["sync_state", "pspecs", "mesh"]
    assert params_of(DT.make_prefill_step) == ["cfg", "max_len", "flags",
                                               "sample"]
    assert params_of(DT.make_decode_step) == ["cfg", "flags", "sample"]
    assert params_of(SH.paged_cache_specs) == ["cfg", "mesh", "pool"]


def test_async_engine_symbols_and_signatures():
    assert params_of(AE.make_async_train_step) == [
        "cfg", "opt", "mesh", "acfg", "pspecs", "flags", "grad_accum"]
    assert params_of(AE.init_async_state) == ["acfg", "mesh", "params_like",
                                              "pspecs"]
    acfg = AE.AsyncConfig()
    # the config surface launch/train + bench_async_ef drive
    assert acfg.tau_max == 0 and acfg.schedule == "uniform"
    assert acfg.compressor == "none" and acfg.error_feedback is True
    assert acfg.capacity == 1 and acfg.has_err is False
    # overlap defaults ON but only changes the program with a compressor
    assert acfg.overlap is True and acfg.fused is False
    assert acfg.kernel_impl == "auto"
    # fault-tolerance knobs default OFF (the fast path traces no guards)
    assert acfg.crash_subst is False and acfg.skip_nonfinite is False
    from repro.core.delivery import DROPPED, TAU_SCHEDULES
    assert acfg.schedule in TAU_SCHEDULES and DROPPED == -1
    # per-worker key registry shared between layout and spec builders
    assert "buf" in SH.PER_WORKER_RING_KEYS
    assert params_of(SH.shard_state_specs) == ["state", "head"]


def test_launch_modules_import():
    """The three launchers resolve their repro.dist imports at module load
    (serve/train import lazily inside main, so exercise those paths via
    importlib on dryrun which imports at toplevel)."""
    import repro.launch.serve  # noqa: F401
    import repro.launch.train  # noqa: F401
    # dryrun imports repro.dist at module scope — importing it IS the check
    import repro.launch.dryrun  # noqa: F401


# ---------------------------------------------------------------------------
# spec-builder behaviour (pure, no multi-device mesh needed)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    from repro.jax_compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_axis_sizes_and_data_axes(mesh):
    assert SH.axis_sizes(mesh) == {"data": 1, "model": 1}
    assert SH.data_axes(mesh) == ("data",)


def test_named_maps_spec_trees(mesh):
    tree = {"a": P(None, "model"), "b": {"c": P()}}
    out = SH.named(mesh, tree)
    assert isinstance(out["a"], NamedSharding)
    assert out["a"].spec == P(None, "model")
    assert out["b"]["c"].spec == P()


def test_batch_spec_divisibility(mesh):
    assert tuple(SH.batch_spec(mesh, 8)) == ("data",)
    # non-divisible batch stays replicated
    from repro.jax_compat import make_mesh
    m3 = make_mesh((1,), ("data",))
    assert tuple(SH.batch_spec(m3, 8)) == ("data",)


def test_opt_state_specs_mirror_params(mesh):
    pspecs = {"w": P(None, "model"), "b": P()}
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
            "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    state = {"count": jax.ShapeDtypeStruct((), jnp.int32), "mu": like}
    out = SH.opt_state_specs(state, pspecs)
    assert out["mu"] == pspecs          # params-shaped entries inherit specs
    assert out["count"] == P()          # scalars replicated


def test_make_act_rules_kinds(mesh):
    from repro.configs import get_config
    cfg = get_config("mixtral-8x7b").reduced()
    rules = SH.make_act_rules(cfg, mesh, batch_size=8, seq_len=64)
    for kind in ("residual", "ffn_hidden", "attn_q", "attn_kv", "logits",
                 "moe_expert", "moe_hidden"):
        assert kind in rules and isinstance(rules[kind], NamedSharding), kind
    # inside shard_map the data axes must be dropped
    inner = SH.make_act_rules(cfg, mesh, batch_size=8, seq_len=64,
                              batch_axes=False)
    for kind, ns in inner.items():
        assert "data" not in jax.tree.leaves(tuple(ns.spec)), kind


# ---------------------------------------------------------------------------
# step-builder behaviour at smoke scale
# ---------------------------------------------------------------------------

def test_elastic_step_runs_on_host_mesh(mesh):
    """One elastic step on the degenerate 1-device mesh: params move, the
    sync state advances, metrics carry the consistency gap."""
    from repro.configs import get_config
    from repro.core.scheduler import SyncConfig
    from repro.data.pipeline import synthetic_batch
    from repro.models import transformer as TF
    from repro.models.params import init_params, param_specs
    from repro.optim import momentum

    cfg = get_config("qwen3-1.7b").reduced()
    flags = TF.RunFlags(remat=False)
    defs = TF.model_defs(cfg)
    pspecs = param_specs(defs, SH.axis_sizes(mesh))
    params = init_params(defs, jax.random.PRNGKey(0))
    opt = momentum(1e-2, 0.9)
    opt_state = opt.init(params)
    scfg = SyncConfig(strategy="elastic", axis_names=("data",), gate="norm")
    sync_state = DT.init_dist_sync_state(scfg, mesh, params)
    # per-worker layout: residual leads with a worker dim of size prod(data)
    lead = jax.tree.leaves(sync_state["residual"])[0].shape[0]
    assert lead == 1  # 1-device mesh
    step = DT.make_elastic_train_step(cfg, opt, mesh, scfg, pspecs, flags)
    batch = synthetic_batch(cfg, 2, 32, seed=0)
    p2, opt_state, sync_state, metrics = jax.jit(step)(
        params, opt_state, sync_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["gap2_over_alpha2"]) >= 0.0
    assert int(sync_state["step"]) == 1
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


def test_serve_steps_roundtrip():
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_batch
    from repro.models import transformer as TF
    from repro.models.params import init_params

    cfg = get_config("qwen3-1.7b").reduced()
    flags = TF.RunFlags(remat=False)
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 2, 8, seed=0)
    batch.pop("labels")
    tok, cache = jax.jit(DT.make_prefill_step(cfg, 12, flags))(params, batch)
    assert tok.shape == (2,) and tok.dtype == jnp.int32
    decode = jax.jit(DT.make_decode_step(cfg, flags), donate_argnums=(1,))
    tok2, cache = decode(params, cache, tok[:, None])
    assert tok2.shape == (2,)
    assert int(cache["pos"]) == 9  # 8 prefill + 1 decode
