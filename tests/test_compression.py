"""Property tests for the compression operators (paper Eq. 25 and App. B.7).

The contraction property ||Q(w) - w||^2 <= gamma ||w||^2 is *the* hypothesis
the elastic-consistency bound for EF methods rests on (Lemma 18) — it is
checked here over random vectors via hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression as C  # noqa: E402

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def _vec(draw, n):
    raw = draw(st.lists(
        st.floats(-100.0, 100.0, allow_nan=False, width=32),
        min_size=n, max_size=n))
    return jnp.asarray(raw, jnp.float32)


@given(st.data(), st.integers(8, 64), st.integers(1, 8))
def test_topk_contraction(data, n, k):
    k = min(k, n)
    w = _vec(data.draw, n)
    q = C.topk_q(w, k)
    lhs = float(jnp.sum((q - w) ** 2))
    rhs = C.topk_gamma(n, k) * float(jnp.sum(w ** 2))
    assert lhs <= rhs + 1e-4


@given(st.data(), st.integers(8, 64))
def test_onebit_contraction(data, n):
    w = _vec(data.draw, n)
    q = C.onebit_q(w)
    lhs = float(jnp.sum((q - w) ** 2))
    rhs = C.onebit_gamma(n) * float(jnp.sum(w ** 2))
    assert lhs <= rhs + 1e-4


@given(st.data(), st.integers(8, 64))
def test_onebit_wire_roundtrip(data, n):
    w = _vec(data.draw, n)
    packed, mp, mn = C.onebit_compress(w)
    dense = C.onebit_decompress(packed, mp, mn, n)
    assert np.allclose(np.asarray(dense), np.asarray(C.onebit_q(w)),
                       atol=1e-5)


def test_qsgd_unbiased():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64,))
    qs = jnp.stack([C.qsgd_q(w, jax.random.fold_in(key, i), levels=4)
                    for i in range(2000)])
    err = float(jnp.max(jnp.abs(jnp.mean(qs, axis=0) - w)))
    assert err < 0.15, err


@given(st.data(), st.integers(16, 64), st.integers(2, 30))
def test_error_feedback_telescopes(data, n, steps):
    """Sum of payloads + final residual == sum of updates (Alg 6 identity):
    nothing is ever lost, only delayed — the EF guarantee."""
    comp = C.topk_compressor(0.25)
    err = jnp.zeros(n)
    total_updates = jnp.zeros(n)
    total_payload = jnp.zeros(n)
    for i in range(steps):
        u = _vec(data.draw, n) * 0.1
        payload, err = C.ef_compress(comp, u, err)
        total_updates += u
        total_payload += payload
    assert np.allclose(np.asarray(total_payload + err),
                       np.asarray(total_updates), atol=1e-3)


def test_ef_residual_bounded():
    """Residual norm stays bounded across many steps (Lemma 18's invariant:
    E||eps||^2 <= (2-g)g/(1-g)^3 M^2 alpha^2)."""
    comp = C.topk_compressor(0.25)
    key = jax.random.PRNGKey(1)
    n, alpha = 128, 0.1
    gamma = C.topk_gamma(n, 32)
    m2 = 1.0 * n  # E||g||^2 for unit-variance entries... scaled below
    err = jnp.zeros(n)
    norms = []
    for i in range(300):
        g = jax.random.normal(jax.random.fold_in(key, i), (n,))
        _, err = C.ef_compress(comp, alpha * g, err)
        norms.append(float(jnp.sum(err ** 2)))
    bound = (2 - gamma) * gamma / (1 - gamma) ** 3 * (alpha ** 2) * n
    assert max(norms[50:]) <= bound * 1.05, (max(norms[50:]), bound)
