"""Chunked parallel forms vs defining sequential recurrences (exact
algebraic equivalence, the strongest SSM-layer correctness check), including
chunk-boundary state handoff and chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.mamba2 import ssd_chunked
from repro.models.ref_recurrent import ssd_sequential, wkv6_sequential
from repro.models.rwkv6 import wkv6_chunked

settings.register_profile("rec", max_examples=10, deadline=None)
settings.load_profile("rec")


def _ssd_inputs(key, b, t, h, hd, n):
    xh = jax.random.normal(key, (b, t, h, hd))
    a = -jax.random.uniform(jax.random.fold_in(key, 1), (b, t, h),
                            minval=0.01, maxval=0.5)
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, t, n))
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, t, n))
    return xh, a, bm, cm


@pytest.mark.parametrize("b,t,h,hd,n", [(2, 256, 2, 16, 8), (1, 128, 4, 8, 4)])
def test_ssd_chunked_equals_sequential(b, t, h, hd, n):
    xh, a, bm, cm = _ssd_inputs(jax.random.PRNGKey(0), b, t, h, hd, n)
    y1, s1 = ssd_chunked(xh, a, bm, cm)
    y2, s2 = ssd_sequential(xh, a, bm, cm)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=1e-3)


def test_ssd_state_handoff():
    """Running two halves with the carried state == running the whole."""
    xh, a, bm, cm = _ssd_inputs(jax.random.PRNGKey(1), 1, 256, 2, 8, 4)
    y_full, s_full = ssd_chunked(xh, a, bm, cm)
    y1, s1 = ssd_chunked(xh[:, :128], a[:, :128], bm[:, :128], cm[:, :128])
    y2, s2 = ssd_chunked(xh[:, 128:], a[:, 128:], bm[:, 128:], cm[:, 128:],
                         state0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s2, s_full, atol=2e-4, rtol=1e-3)


def _wkv_inputs(key, b, t, h, n):
    r = jax.random.normal(key, (b, t, h, n))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, n))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, h, n))
    lw = -jax.random.uniform(jax.random.fold_in(key, 3), (b, t, h, n),
                             minval=0.01, maxval=1.0)
    u = 0.5 * jax.random.normal(jax.random.fold_in(key, 4), (h, n))
    return r, k, v, lw, u


@pytest.mark.parametrize("b,t,h,n", [(2, 128, 2, 8), (1, 256, 1, 16)])
def test_wkv6_chunked_equals_sequential(b, t, h, n):
    r, k, v, lw, u = _wkv_inputs(jax.random.PRNGKey(2), b, t, h, n)
    y1, s1 = wkv6_chunked(r, k, v, lw, u)
    y2, s2 = wkv6_sequential(r, k, v, lw, u)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=1e-3)


def test_wkv6_state_handoff():
    r, k, v, lw, u = _wkv_inputs(jax.random.PRNGKey(3), 1, 128, 2, 8)
    y_full, s_full = wkv6_chunked(r, k, v, lw, u)
    y1, s1 = wkv6_chunked(r[:, :64], k[:, :64], v[:, :64], lw[:, :64], u)
    y2, s2 = wkv6_chunked(r[:, 64:], k[:, 64:], v[:, 64:], lw[:, 64:], u,
                          state0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s2, s_full, atol=2e-4, rtol=1e-3)


@given(st.integers(0, 10_000))
def test_ssd_decay_never_amplifies(seed):
    """Property: with zero input after t0, the state norm is non-increasing
    (decays are in (0,1)) — the stability invariant of the SSD recurrence."""
    key = jax.random.PRNGKey(seed)
    xh, a, bm, cm = _ssd_inputs(key, 1, 128, 2, 8, 4)
    xh = xh.at[:, 64:].set(0.0)
    _, s_mid = ssd_sequential(xh[:, :64], a[:, :64], bm[:, :64], cm[:, :64])
    _, s_end = ssd_sequential(xh[:, 64:], a[:, 64:], bm[:, 64:], cm[:, 64:],
                              state0=s_mid)
    assert float(jnp.linalg.norm(s_end)) <= float(
        jnp.linalg.norm(s_mid)) * (1 + 1e-5)
