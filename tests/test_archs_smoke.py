"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch runs one forward + one train step on CPU; output shapes and
finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist modules not seeded in this snapshot")

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.data.pipeline import synthetic_batch  # noqa: E402
from repro.dist.train import loss_fn, make_train_step  # noqa: E402
from repro.models import transformer as TF
from repro.models.params import count_params, init_params
from repro.optim import momentum

FLAGS = TF.RunFlags(remat=False)
B, S = 2, 64


def _batch(cfg):
    return synthetic_batch(cfg, B, S, seed=0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    logits, aux = jax.jit(
        lambda p, b: TF.forward(cfg, p, b, FLAGS))(params, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(TF.model_defs(cfg), jax.random.PRNGKey(0))
    opt = momentum(1e-3, 0.9)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, FLAGS))
    batch = _batch(cfg)
    loss0 = float(loss_fn(cfg, params, batch, FLAGS)[0])
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0
    loss1 = float(loss_fn(cfg, params2, batch, FLAGS)[0])
    assert np.isfinite(loss1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned hyperparams."""
    cfg = get_config(arch)
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    if arch == "grok-1-314b":
        assert (cfg.n_experts, cfg.experts_per_token) == (8, 2)
    if arch == "moonshot-v1-16b-a3b":
        assert (cfg.n_experts, cfg.experts_per_token) == (64, 6)
    if arch == "mixtral-8x7b":
        assert (cfg.n_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window == 4096
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every == 6
    if arch == "gemma3-27b":
        assert cfg.sliding_window == 1024 and cfg.global_every == 6
    if arch == "qwen3-1.7b":
        assert cfg.qk_norm


def test_param_counts_plausible():
    # grok-1 is the 314B-class config
    assert 2.5e11 < get_config("grok-1-314b").param_count() < 4e11
    assert 3.5e10 < get_config("mixtral-8x7b").param_count() < 5.5e10
    assert 1.2e9 < get_config("qwen3-1.7b").param_count() < 2.5e9
    # moonshot activates ~3B of ~16B
    ms = get_config("moonshot-v1-16b-a3b")
    assert ms.active_param_count() < 0.45 * ms.param_count()
